//! Difficulty-aware auto protocol selection (DESIGN.md §14).
//!
//! The paper's Figure-1 finding is that the five concrete protocols sit
//! on a cost/quality trade-off curve — LocalOnly is free but weak,
//! RemoteOnly strong but expensive, Minion/MinionS between — yet the
//! caller has always had to pick the rung by hand. This module ships
//! the `kind: "auto"` spec: a meta-protocol whose resolution runs a
//! cheap **difficulty probe** over the request (document length and
//! chunk count, question-type features from the query, and a one-shot
//! local confidence score through the ordinary cached scoring path) and
//! combines it with **live scheduler signals** (lane depth, admission
//! saturation, mean wait) under a configurable cost function
//! (`route_weights = latency:cost:quality`) to select one concrete
//! [`ProtocolSpec`], resolved through the memoizing
//! [`ProtocolFactory`](crate::protocol::factory::ProtocolFactory) like
//! any hand-picked spec.
//!
//! ## Determinism contract
//!
//! Routing consults *live* queue state, so the decision is only
//! reproducible at the moment it is made. The rule, therefore: a
//! decision is computed **exactly once**, serialized as the `routed`
//! payload of the session's WAL meta record (v3, see
//! [`crate::server::wal`]) *before* the session becomes observable, and
//! every replay path — crash recovery, fleet migration — reuses the
//! persisted decision instead of re-probing. Every float inside the
//! payload travels as hex bit patterns ([`f64_to_json`]) so re-encoding
//! a parsed decision reproduces the original bytes.
//!
//! [`route`] itself is a pure function of `(spec, features, signals)`:
//! same inputs, same chosen rung, bit-identical decision JSON. Ties
//! break toward the cheaper rung in ladder order
//! (`local → rag-bm25 → minion → minions → remote`).
//!
//! ## The cost function
//!
//! For each allowed rung the router estimates quality, dollar cost, and
//! latency (in abstract scheduler-pass units), normalizes each column
//! by its maximum across the candidates, and minimizes
//!
//! ```text
//! score = (w_l·latency̅ + w_c·cost̅ + w_q·(1 − quality̅)) / (w_l+w_c+w_q)
//! ```
//!
//! mirroring the EdgeCloudManager energy/latency/memory weighting in
//! SNIPPETS.md. Quality estimates are difficulty-modulated: a hard
//! request collapses LocalOnly's estimate toward zero while barely
//! denting MinionS/RemoteOnly — the "easy tokens stay local" idea from
//! MiniLLM, lifted from tokens to whole requests.

use crate::cost::CostModel;
use crate::data::{Sample, PAGES_PER_CHUNK_MAX, PAGE_TOKENS};
use crate::model::LocalLm;
use crate::protocol::spec::{
    fnv1a64, ProtocolKind, ProtocolSpec, DEFAULT_LOCAL, DEFAULT_REMOTE, DEFAULT_TOP_K,
};
use crate::protocol::{f64_from_json, f64_to_json, u64_to_json};
use crate::rag::Retriever;
use crate::sched::{BatcherSnapshot, Lane};
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// The wire name of the auto kind (CLI `--protocol auto`, JSON
/// `{"kind":"auto"}`).
pub const AUTO_KIND: &str = "auto";

/// Every routable rung in ladder order, cheapest first — the iteration
/// order of the selector and its tie-break.
pub const LADDER: [ProtocolKind; 6] = [
    ProtocolKind::LocalOnly,
    ProtocolKind::RagBm25,
    ProtocolKind::RagDense,
    ProtocolKind::Minion,
    ProtocolKind::Minions,
    ProtocolKind::RemoteOnly,
];

/// A rung's position in [`LADDER`] — the index the server's per-rung
/// `router_chosen_*` counters use. Total over every kind.
pub fn ladder_index(kind: ProtocolKind) -> usize {
    LADDER.iter().position(|&k| k == kind).unwrap_or(0)
}

/// The default `allowed` set: one rung per protocol family (the dense
/// retriever is an opt-in alternative to BM25, not a distinct rung).
pub fn default_allowed() -> Vec<ProtocolKind> {
    vec![
        ProtocolKind::LocalOnly,
        ProtocolKind::RagBm25,
        ProtocolKind::Minion,
        ProtocolKind::Minions,
        ProtocolKind::RemoteOnly,
    ]
}

/// Ceiling on the probe budget (spans scored by the confidence probe).
pub const PROBE_BUDGET_CAP: usize = 32;
/// Default spans scored by the one-shot confidence probe.
pub const DEFAULT_PROBE_BUDGET: usize = 4;
/// Ceiling on each route weight (they are small integers by design so
/// the canonical form needs no float formatting).
pub const ROUTE_WEIGHT_CAP: u64 = 100;

/// The `latency:cost:quality` weight triple. Weights are small
/// non-negative integers (not floats) so the canonical wire form —
/// the `"L:C:Q"` string — is exact and fingerprint-stable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RouteWeights {
    pub latency: u64,
    pub cost: u64,
    pub quality: u64,
}

impl Default for RouteWeights {
    fn default() -> RouteWeights {
        RouteWeights {
            latency: 1,
            cost: 1,
            quality: 1,
        }
    }
}

impl RouteWeights {
    /// Parse `"latency:cost:quality"`, e.g. `"1:2:4"`. Each part is an
    /// integer in `0..=100`; at least one must be positive.
    pub fn parse(s: &str) -> Result<RouteWeights> {
        let parts: Vec<&str> = s.split(':').collect();
        let &[l, c, q] = parts.as_slice() else {
            return Err(anyhow!(
                "route_weights must be 'latency:cost:quality' (e.g. '1:1:1'), got '{s}'"
            ));
        };
        let num = |name: &str, part: &str| -> Result<u64> {
            let v: u64 = part
                .trim()
                .parse()
                .map_err(|_| anyhow!("route_weights {name} must be an integer, got '{part}'"))?;
            if v > ROUTE_WEIGHT_CAP {
                return Err(anyhow!(
                    "route_weights {name} must be 0..={ROUTE_WEIGHT_CAP}, got {v}"
                ));
            }
            Ok(v)
        };
        let w = RouteWeights {
            latency: num("latency", l)?,
            cost: num("cost", c)?,
            quality: num("quality", q)?,
        };
        if w.latency + w.cost + w.quality == 0 {
            return Err(anyhow!("route_weights must not all be zero, got '{s}'"));
        }
        Ok(w)
    }

    /// The canonical wire form (`parse` ∘ `as_string` is identity).
    pub fn as_string(&self) -> String {
        format!("{}:{}:{}", self.latency, self.cost, self.quality)
    }
}

/// A validated `kind: "auto"` specification: the routing policy, not a
/// protocol. Parallels [`ProtocolSpec`] — canonical JSON with sorted
/// keys and defaults filled, FNV-1a-64 fingerprint — but resolves to a
/// *decision* per request rather than to one protocol instance.
#[derive(Clone, Debug, PartialEq)]
pub struct AutoSpec {
    /// local model profile used both by the probe and by any routed
    /// local-side rung
    pub local: String,
    /// remote model profile for any routed remote-side rung
    pub remote: String,
    /// the latency:cost:quality cost-function weights
    pub weights: RouteWeights,
    /// max spans the one-shot confidence probe scores (1..=32)
    pub probe_budget: usize,
    /// candidate rungs, stored in ladder order (deduplicated)
    pub allowed: Vec<ProtocolKind>,
}

impl Default for AutoSpec {
    fn default() -> AutoSpec {
        AutoSpec {
            local: DEFAULT_LOCAL.to_string(),
            remote: DEFAULT_REMOTE.to_string(),
            weights: RouteWeights::default(),
            probe_budget: DEFAULT_PROBE_BUDGET,
            allowed: default_allowed(),
        }
    }
}

impl AutoSpec {
    /// Whether a JSON spec object names the auto kind (the dispatch
    /// test run before [`ProtocolSpec::from_json`], which rejects it).
    pub fn is_auto(j: &Json) -> bool {
        j.get("kind").and_then(Json::as_str) == Some(AUTO_KIND)
    }

    /// Parse and validate from the JSON object form. Accepts any key
    /// order, fills defaults, rejects unknown fields.
    pub fn from_json(j: &Json) -> Result<AutoSpec> {
        let Json::Obj(map) = j else {
            return Err(anyhow!("auto spec must be a JSON object, got {j}"));
        };
        let mut spec = AutoSpec::default();
        for (key, value) in map {
            match key.as_str() {
                "kind" => {
                    if value.as_str() != Some(AUTO_KIND) {
                        return Err(anyhow!("auto spec kind must be \"auto\", got {value}"));
                    }
                }
                "local" => {
                    spec.local = value
                        .as_str()
                        .ok_or_else(|| anyhow!("auto spec field 'local' must be a string"))?
                        .to_string();
                }
                "remote" => {
                    spec.remote = value
                        .as_str()
                        .ok_or_else(|| anyhow!("auto spec field 'remote' must be a string"))?
                        .to_string();
                }
                "route_weights" => {
                    let s = value.as_str().ok_or_else(|| {
                        anyhow!("auto spec field 'route_weights' must be a string")
                    })?;
                    spec.weights = RouteWeights::parse(s)?;
                }
                "probe_budget" => {
                    let n = match value.as_f64() {
                        Some(n) if n.fract() == 0.0 && n >= 1.0 && n <= PROBE_BUDGET_CAP as f64 => {
                            n as usize
                        }
                        _ => {
                            return Err(anyhow!(
                                "auto spec field 'probe_budget' must be 1..={PROBE_BUDGET_CAP}, \
                                 got {value}"
                            ))
                        }
                    };
                    spec.probe_budget = n;
                }
                "allowed" => {
                    let Json::Arr(items) = value else {
                        return Err(anyhow!(
                            "auto spec field 'allowed' must be an array of protocol kinds"
                        ));
                    };
                    let mut allowed = Vec::new();
                    for item in items {
                        let name = item.as_str().ok_or_else(|| {
                            anyhow!("auto spec 'allowed' entries must be strings, got {item}")
                        })?;
                        let kind = ProtocolKind::parse(name)?;
                        if !allowed.contains(&kind) {
                            allowed.push(kind);
                        }
                    }
                    if allowed.is_empty() {
                        return Err(anyhow!("auto spec 'allowed' must name at least one kind"));
                    }
                    // canonical order is ladder order, whatever arrived
                    spec.allowed = LADDER
                        .into_iter()
                        .filter(|k| allowed.contains(k))
                        .collect();
                }
                other => {
                    return Err(anyhow!(
                        "unknown auto spec field '{other}' (allowed: kind, local, remote, \
                         route_weights, probe_budget, allowed)"
                    ))
                }
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    /// [`AutoSpec::from_json`] over a raw JSON string.
    pub fn parse(s: &str) -> Result<AutoSpec> {
        let j = Json::parse(s).map_err(|e| anyhow!("auto spec is not valid JSON: {e}"))?;
        AutoSpec::from_json(&j)
    }

    /// Validate the profile names by constructing a throwaway concrete
    /// spec per side — the same resolution the routed rung will run —
    /// plus the policy knobs (directly-constructed specs, e.g. from CLI
    /// flags, bypass `from_json`'s field checks).
    pub fn validate(&self) -> Result<()> {
        ProtocolSpec::local_only(&self.local).validate()?;
        ProtocolSpec::remote_only(&self.remote).validate()?;
        if self.allowed.is_empty() {
            return Err(anyhow!("auto spec 'allowed' must name at least one kind"));
        }
        if !(1..=PROBE_BUDGET_CAP).contains(&self.probe_budget) {
            return Err(anyhow!(
                "probe_budget must be 1..={PROBE_BUDGET_CAP}, got {}",
                self.probe_budget
            ));
        }
        // `RouteWeights::parse` enforces both bounds on the wire path;
        // re-check here for struct-literal construction
        let w = &self.weights;
        if w.latency + w.cost + w.quality == 0 {
            return Err(anyhow!("route_weights must not all be zero"));
        }
        if w.latency.max(w.cost).max(w.quality) > ROUTE_WEIGHT_CAP {
            return Err(anyhow!("route_weights must each be 0..={ROUTE_WEIGHT_CAP}"));
        }
        Ok(())
    }

    /// Canonical JSON: every field present, keys sorted, `allowed` in
    /// ladder order — a fixed point under parse ∘ canonical.
    pub fn canonical(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(AUTO_KIND)),
            ("local", Json::str(self.local.clone())),
            ("remote", Json::str(self.remote.clone())),
            ("route_weights", Json::str(self.weights.as_string())),
            ("probe_budget", Json::num(self.probe_budget as f64)),
            (
                "allowed",
                Json::Arr(self.allowed.iter().map(|k| Json::str(k.as_str())).collect()),
            ),
        ])
    }

    pub fn canonical_string(&self) -> String {
        self.canonical().to_string()
    }

    /// Stable identity over the canonical string — what the gateway's
    /// consistent hash keys on at create time (post-create it re-keys
    /// on the *resolved* spec's fingerprint from the WAL meta).
    pub fn fingerprint(&self) -> u64 {
        fnv1a64(self.canonical_string().as_bytes())
    }

    /// The concrete candidate spec for one allowed rung: the auto
    /// spec's profile names, every other knob at its default.
    pub fn candidate(&self, kind: ProtocolKind) -> ProtocolSpec {
        match kind {
            ProtocolKind::LocalOnly => ProtocolSpec::local_only(&self.local),
            ProtocolKind::RemoteOnly => ProtocolSpec::remote_only(&self.remote),
            ProtocolKind::RagBm25 => {
                ProtocolSpec::rag(Retriever::Bm25, &self.remote, DEFAULT_TOP_K)
            }
            ProtocolKind::RagDense => {
                ProtocolSpec::rag(Retriever::Dense, &self.remote, DEFAULT_TOP_K)
            }
            ProtocolKind::Minion => {
                let mut s = ProtocolSpec::new(ProtocolKind::Minion);
                s.local = self.local.clone();
                s.remote = self.remote.clone();
                s
            }
            ProtocolKind::Minions => ProtocolSpec::minions(&self.local, &self.remote),
        }
    }
}

/// The per-field discovery document for the auto kind, merged into
/// `GET /v1/protocols` alongside [`crate::protocol::spec::schema_json`].
pub fn auto_schema_json() -> Json {
    let field = |help: String, default: Json| {
        Json::obj(vec![
            ("help", Json::str(help)),
            ("default", default),
            ("applies_to", Json::Arr(vec![Json::str(AUTO_KIND)])),
        ])
    };
    Json::obj(vec![
        (
            "kind",
            field("the auto-routing meta protocol (required)".to_string(), Json::Null),
        ),
        (
            "local",
            field(
                "local profile for the probe and any routed local-side rung".to_string(),
                Json::str(DEFAULT_LOCAL),
            ),
        ),
        (
            "remote",
            field(
                "remote profile for any routed remote-side rung".to_string(),
                Json::str(DEFAULT_REMOTE),
            ),
        ),
        (
            "route_weights",
            field(
                format!(
                    "latency:cost:quality cost-function weights, integers 0..={ROUTE_WEIGHT_CAP} \
                     (not all zero)"
                ),
                Json::str(RouteWeights::default().as_string()),
            ),
        ),
        (
            "probe_budget",
            field(
                format!("max spans the confidence probe scores (1..={PROBE_BUDGET_CAP})"),
                Json::num(DEFAULT_PROBE_BUDGET as f64),
            ),
        ),
        (
            "allowed",
            field(
                "candidate rungs the router may choose from (ladder order)".to_string(),
                Json::Arr(LADDER.iter().map(|k| Json::str(k.as_str())).collect()),
            ),
        ),
    ])
}

/// The request-shape half of the feature vector (everything but the
/// probe confidence), extracted from a [`Sample`] with no scoring.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Features {
    pub docs: usize,
    pub pages: usize,
    pub context_tokens: usize,
    /// full-width chunk count — the unit of local decompose work
    pub chunks: usize,
    /// fact keys the query names
    pub keys: usize,
    /// query class (wire name of the [`crate::data::QueryKind`])
    pub query_kind: QueryClass,
    /// one-shot local confidence from the probe, clamped to [0,1]
    pub confidence: f64,
}

/// Closed query-type classification with a per-class difficulty prior.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryClass {
    Extract,
    Bool,
    Compute,
    Multi,
    Summarize,
}

impl QueryClass {
    pub fn of(sample: &Sample) -> QueryClass {
        use crate::data::QueryKind;
        match sample.query.kind {
            QueryKind::Extract => QueryClass::Extract,
            QueryKind::Bool => QueryClass::Bool,
            QueryKind::Compute(_) => QueryClass::Compute,
            QueryKind::Multi(_) => QueryClass::Multi,
            QueryKind::Summarize => QueryClass::Summarize,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            QueryClass::Extract => "extract",
            QueryClass::Bool => "bool",
            QueryClass::Compute => "compute",
            QueryClass::Multi => "multi",
            QueryClass::Summarize => "summarize",
        }
    }

    /// Difficulty prior in [0,1]: how much exact multi-part reasoning
    /// the class demands beyond single-fact lookup.
    fn prior(&self, keys: usize) -> f64 {
        match self {
            QueryClass::Extract => 0.15,
            QueryClass::Bool => 0.20,
            QueryClass::Compute => 0.45,
            QueryClass::Multi => (0.30 + 0.10 * keys as f64).min(0.70),
            QueryClass::Summarize => 0.60,
        }
    }
}

impl Features {
    /// Extract the shape features from `sample`; `confidence` comes
    /// from [`probe_confidence`] (or 0.0 when no probe ran).
    pub fn extract(sample: &Sample, confidence: f64) -> Features {
        let pages = sample.context.total_pages();
        Features {
            docs: sample.context.docs.len(),
            pages,
            context_tokens: sample.context.total_tokens(),
            chunks: pages.div_ceil(PAGES_PER_CHUNK_MAX),
            keys: sample.query.keys.len(),
            query_kind: QueryClass::of(sample),
            confidence: confidence.clamp(0.0, 1.0),
        }
    }

    /// Scalar difficulty in [0,1]: size, query class, and (inverted)
    /// probe confidence, each capped so no single term saturates it.
    pub fn difficulty(&self) -> f64 {
        let size = ((1.0 + self.chunks as f64).ln() / (1.0 + 32.0f64).ln()).min(1.0);
        let query = self.query_kind.prior(self.keys);
        let doubt = 1.0 - self.confidence;
        (0.35 * size + 0.35 * query + 0.30 * doubt).clamp(0.0, 1.0)
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("chunks", Json::num(self.chunks as f64)),
            ("confidence", f64_to_json(self.confidence)),
            ("context_tokens", Json::num(self.context_tokens as f64)),
            ("difficulty", f64_to_json(self.difficulty())),
            ("docs", Json::num(self.docs as f64)),
            ("keys", Json::num(self.keys as f64)),
            ("pages", Json::num(self.pages as f64)),
            ("query_kind", Json::str(self.query_kind.as_str())),
        ])
    }
}

/// One-shot local confidence: score up to `budget` evenly-spaced pages
/// against the query's first key through the ordinary cached scoring
/// path (a cache hit costs nothing; a miss warms the cache for the
/// routed protocol). Returns the best span relevance, clamped to
/// [0,1]. Consumes **no** rng — the session's stream is untouched.
pub fn probe_confidence(local: &LocalLm, sample: &Sample, budget: usize) -> Result<f64> {
    let Some(key) = sample.query.keys.first() else {
        return Ok(0.0); // keyless query: nothing to probe, assume hard
    };
    let pages: Vec<&Vec<u32>> = sample.context.docs.iter().flat_map(|d| &d.pages).collect();
    if pages.is_empty() {
        return Ok(0.0);
    }
    let budget = budget.clamp(1, PROBE_BUDGET_CAP).min(pages.len());
    // evenly spaced page picks, deterministic in document order
    let spans: Vec<Vec<u32>> = (0..budget)
        .filter_map(|i| pages.get(i * pages.len() / budget).map(|p| (*p).clone()))
        .collect();
    let scores = local.score_span(key, &spans)?;
    let best = scores.iter().fold(0.0f32, |a, &s| a.max(s));
    Ok((best as f64).clamp(0.0, 1.0))
}

fn lane_at(depths: &[usize; Lane::COUNT], lane: Lane) -> usize {
    depths.get(lane.index()).copied().unwrap_or(0)
}

/// Live scheduler state at decision time, snapshotted from the shared
/// batcher. [`Signals::idle`] is the zero state for offline callers
/// (CLI runs, the bench exhibit) with no live queue.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Signals {
    pub queue_depth: usize,
    pub lane_interactive: usize,
    pub lane_batch: usize,
    pub saturated: u64,
    pub high_water: bool,
    pub interactive_wait_us: f64,
}

impl Signals {
    pub fn idle() -> Signals {
        Signals::default()
    }

    pub fn from_snapshot(snap: &BatcherSnapshot, high_water: bool) -> Signals {
        Signals {
            queue_depth: snap.queue_depth,
            lane_interactive: lane_at(&snap.lane_depth, Lane::Interactive),
            lane_batch: lane_at(&snap.lane_depth, Lane::Batch),
            saturated: snap.saturated,
            high_water,
            interactive_wait_us: snap.lane_mean_wait_us(Lane::Interactive),
        }
    }

    /// Local-engine pressure in [0,1]: how much a rung that schedules
    /// many local scoring rows will queue behind existing work.
    pub fn pressure(&self) -> f64 {
        let depth = self.queue_depth as f64 / 128.0;
        let hw = if self.high_water { 0.5 } else { 0.0 };
        let sat = if self.saturated > 0 { 0.25 } else { 0.0 };
        (depth + hw + sat).clamp(0.0, 1.0)
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("high_water", Json::Bool(self.high_water)),
            ("lane_batch", Json::num(self.lane_batch as f64)),
            ("lane_interactive", Json::num(self.lane_interactive as f64)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("saturated", Json::num(self.saturated as f64)),
            ("wait_us", f64_to_json(self.interactive_wait_us)),
        ])
    }
}

/// Per-candidate cost-function evaluation, kept for the decision log.
#[derive(Clone, Copy, Debug)]
pub struct CandidateScore {
    pub kind: ProtocolKind,
    pub quality: f64,
    pub cost_usd: f64,
    pub latency: f64,
    pub score: f64,
}

impl CandidateScore {
    fn to_json(self) -> Json {
        Json::obj(vec![
            ("cost_usd", f64_to_json(self.cost_usd)),
            ("kind", Json::str(self.kind.as_str())),
            ("latency", f64_to_json(self.latency)),
            ("quality", f64_to_json(self.quality)),
            ("score", f64_to_json(self.score)),
        ])
    }
}

/// A completed routing decision: the chosen concrete spec plus the full
/// evidence trail (features, signals, per-candidate scores) — exactly
/// what the WAL meta v3 `routed` payload persists.
#[derive(Clone, Debug)]
pub struct RouteDecision {
    pub auto: AutoSpec,
    pub chosen: ProtocolSpec,
    pub features: Features,
    pub signals: Signals,
    pub scores: Vec<CandidateScore>,
}

impl RouteDecision {
    /// The deterministic JSON payload. All floats are hex bit patterns,
    /// so parse → re-encode reproduces these bytes exactly (the WAL
    /// byte-identity contract under recovery and adoption).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("auto", self.auto.canonical()),
            ("chosen", self.chosen.canonical()),
            ("chosen_kind", Json::str(self.chosen.kind.as_str())),
            ("features", self.features.to_json()),
            ("fingerprint", u64_to_json(self.chosen.fingerprint())),
            (
                "scores",
                Json::Arr(self.scores.iter().map(|s| s.to_json()).collect()),
            ),
            ("signals", self.signals.to_json()),
        ])
    }
}

/// Pull the resolved concrete spec back out of a persisted `routed`
/// payload — the replay path's inverse of [`RouteDecision::to_json`].
pub fn routed_spec(routed: &Json) -> Result<ProtocolSpec> {
    let chosen = routed
        .get("chosen")
        .ok_or_else(|| anyhow!("routed payload missing 'chosen' spec"))?;
    ProtocolSpec::from_json(chosen)
}

/// A compact human-readable summary of a persisted decision (status
/// bodies, CLI). Never fails: unknown shapes degrade to "?".
pub fn routed_summary(routed: &Json) -> String {
    let kind = routed
        .get("chosen_kind")
        .and_then(Json::as_str)
        .unwrap_or("?");
    let difficulty = routed
        .get("features")
        .and_then(|f| f.get("difficulty"))
        .and_then(|d| f64_from_json(d).ok())
        .unwrap_or(f64::NAN);
    format!("auto->{kind} (difficulty {:.3})", difficulty)
}

// Per-rung quality estimate: `base - sensitivity * difficulty`,
// clamped. Bases and sensitivities encode the paper's Figure-1
// ordering (LocalOnly matches the frontier on easy requests and
// collapses on hard ones; MinionS tracks RemoteOnly closely).
fn est_quality(kind: ProtocolKind, difficulty: f64) -> f64 {
    let (base, sensitivity) = match kind {
        ProtocolKind::LocalOnly => (0.95, 0.90),
        ProtocolKind::RagBm25 | ProtocolKind::RagDense => (0.90, 0.55),
        ProtocolKind::Minion => (0.92, 0.35),
        ProtocolKind::Minions => (0.97, 0.15),
        ProtocolKind::RemoteOnly => (0.98, 0.05),
    };
    (base - sensitivity * difficulty).clamp(0.0, 1.0)
}

/// Tokens in one full-width chunk (the RAG/remote shipping unit).
const CHUNK_TOKENS: usize = PAGE_TOKENS * PAGES_PER_CHUNK_MAX;
/// Flat token allowance for a query's surface form plus instructions.
const QUERY_TOKENS: f64 = 64.0;
/// Rounds a MinionS run typically needs (paper: most converge in ≤ 2).
const MINIONS_ROUNDS_EST: f64 = 2.0;
/// Abstract latency of one remote round-trip, in local-pass units
/// (mirrors the cost model's decode premium α).
const REMOTE_TRIP_UNITS: f64 = 4.0;

// Estimated (remote_prefill, remote_decode) token counts per rung.
fn est_remote_tokens(kind: ProtocolKind, f: &Features, spec: &ProtocolSpec) -> (f64, f64) {
    match kind {
        ProtocolKind::LocalOnly => (0.0, 0.0),
        ProtocolKind::RagBm25 | ProtocolKind::RagDense => (
            spec.top_k as f64 * CHUNK_TOKENS as f64 + QUERY_TOKENS,
            QUERY_TOKENS,
        ),
        ProtocolKind::Minion => {
            let rounds = spec.max_rounds as f64;
            (rounds * 6.0 * QUERY_TOKENS, rounds * 1.5 * QUERY_TOKENS)
        }
        ProtocolKind::Minions => {
            let tasks = spec.tasks_per_round as f64;
            (
                MINIONS_ROUNDS_EST * (4.0 * QUERY_TOKENS + tasks * QUERY_TOKENS),
                MINIONS_ROUNDS_EST * (tasks * 0.5 * QUERY_TOKENS + QUERY_TOKENS),
            )
        }
        ProtocolKind::RemoteOnly => (f.context_tokens as f64 + QUERY_TOKENS, QUERY_TOKENS),
    }
}

// Abstract latency estimate: local scoring passes inflated by live
// queue pressure, plus remote round-trips at a fixed premium.
fn est_latency(kind: ProtocolKind, f: &Features, s: &Signals, spec: &ProtocolSpec) -> f64 {
    let chunks = f.chunks.max(1) as f64;
    let (local_passes, remote_trips) = match kind {
        ProtocolKind::LocalOnly => (chunks, 0.0),
        ProtocolKind::RagBm25 | ProtocolKind::RagDense => (1.0, 1.0),
        ProtocolKind::Minion => (spec.max_rounds as f64, spec.max_rounds as f64),
        ProtocolKind::Minions => (
            MINIONS_ROUNDS_EST * chunks * spec.samples_per_task as f64 / 8.0,
            MINIONS_ROUNDS_EST + 1.0,
        ),
        ProtocolKind::RemoteOnly => (0.0, 1.0),
    };
    local_passes * (1.0 + 3.0 * s.pressure()) + remote_trips * REMOTE_TRIP_UNITS
}

/// Select a rung: the pure core of the router (see module docs).
/// Deterministic in its inputs; ties break toward the cheaper rung.
pub fn route(auto: &AutoSpec, features: &Features, signals: &Signals) -> RouteDecision {
    let difficulty = features.difficulty();
    let model = CostModel::GPT4O_JAN2025;
    let mut raw: Vec<CandidateScore> = Vec::with_capacity(auto.allowed.len());
    for &kind in &auto.allowed {
        let spec = auto.candidate(kind);
        let (prefill, decode) = est_remote_tokens(kind, features, &spec);
        let cost_usd =
            prefill * model.usd_per_m_input / 1e6 + decode * model.usd_per_m_output / 1e6;
        raw.push(CandidateScore {
            kind,
            quality: est_quality(kind, difficulty),
            cost_usd,
            latency: est_latency(kind, features, signals, &spec),
            score: 0.0,
        });
    }
    let max_cost = raw.iter().fold(0.0f64, |a, c| a.max(c.cost_usd));
    let max_lat = raw.iter().fold(0.0f64, |a, c| a.max(c.latency));
    let w = &auto.weights;
    let w_total = (w.latency + w.cost + w.quality) as f64;
    for c in &mut raw {
        let costn = if max_cost > 0.0 { c.cost_usd / max_cost } else { 0.0 };
        let latn = if max_lat > 0.0 { c.latency / max_lat } else { 0.0 };
        c.score = (w.latency as f64 * latn
            + w.cost as f64 * costn
            + w.quality as f64 * (1.0 - c.quality))
            / w_total;
    }
    // first strict minimum in ladder order = deterministic tie-break
    let mut chosen_kind = raw.first().map(|c| c.kind).unwrap_or(ProtocolKind::Minions);
    let mut best = f64::INFINITY;
    for c in &raw {
        if c.score < best {
            best = c.score;
            chosen_kind = c.kind;
        }
    }
    RouteDecision {
        auto: auto.clone(),
        chosen: auto.candidate(chosen_kind),
        features: *features,
        signals: *signals,
        scores: raw,
    }
}

/// Probe + route in one call: the path the server, the CLI, and the
/// bench exhibit all share. `signals` is the caller's view of the live
/// scheduler ([`Signals::idle`] offline).
pub fn route_sample(
    auto: &AutoSpec,
    sample: &Sample,
    probe: &LocalLm,
    signals: &Signals,
) -> Result<RouteDecision> {
    let confidence = probe_confidence(probe, sample, auto.probe_budget)?;
    let features = Features::extract(sample, confidence);
    Ok(route(auto, &features, signals))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    fn sample(dataset: &str, id: usize) -> Sample {
        let mut ds = data::generate(dataset, id + 1, 7);
        ds.samples.remove(id)
    }

    #[test]
    fn route_weights_parse_and_round_trip() {
        let w = RouteWeights::parse("1:2:4").unwrap();
        assert_eq!(
            w,
            RouteWeights {
                latency: 1,
                cost: 2,
                quality: 4
            }
        );
        assert_eq!(RouteWeights::parse(&w.as_string()).unwrap(), w);
        assert!(RouteWeights::parse("0:0:0").is_err());
        assert!(RouteWeights::parse("1:2").is_err());
        assert!(RouteWeights::parse("1:2:x").is_err());
        assert!(RouteWeights::parse("1:2:101").is_err());
    }

    #[test]
    fn auto_spec_canonical_is_a_fixed_point() {
        let spec = AutoSpec::default();
        let canon = spec.canonical_string();
        let back = AutoSpec::parse(&canon).unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.canonical_string(), canon);
        assert_eq!(back.fingerprint(), spec.fingerprint());
        // key order and allowed order are both normalized away
        let c = AutoSpec::parse(
            r#"{"route_weights":"1:1:1","kind":"auto","allowed":["remote","local","minions","minion","rag-bm25"]}"#,
        )
        .unwrap();
        assert_eq!(c.fingerprint(), spec.fingerprint());
        assert_eq!(c.allowed, default_allowed());
    }

    #[test]
    fn auto_spec_rejects_bad_fields_with_helpful_messages() {
        let err = AutoSpec::parse(r#"{"kind":"minions"}"#).unwrap_err().to_string();
        assert!(err.contains("kind must be \"auto\""), "{err}");
        let err = AutoSpec::parse(r#"{"kind":"auto","probe_budget":0}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("probe_budget"), "{err}");
        let err = AutoSpec::parse(r#"{"kind":"auto","allowed":[]}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("at least one kind"), "{err}");
        let err = AutoSpec::parse(r#"{"kind":"auto","allowed":["warp"]}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown protocol 'warp'"), "{err}");
        let err = AutoSpec::parse(r#"{"kind":"auto","budget":3}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown auto spec field 'budget'"), "{err}");
        let err = AutoSpec::parse(r#"{"kind":"auto","local":"llama-9t"}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown local profile"), "{err}");
    }

    #[test]
    fn easy_confident_requests_stay_local() {
        let auto = AutoSpec::default();
        let s = sample("finance", 0);
        let mut f = Features::extract(&s, 0.98);
        f.chunks = 1;
        f.pages = 2;
        f.context_tokens = 256;
        f.query_kind = QueryClass::Extract;
        let d = route(&auto, &f, &Signals::idle());
        assert_eq!(d.chosen.kind, ProtocolKind::LocalOnly, "{:?}", d.scores);
        assert_eq!(d.chosen.local, auto.local);
    }

    #[test]
    fn hard_unconfident_requests_escalate_off_local() {
        let auto = AutoSpec::default();
        let s = sample("qasper", 0);
        let mut f = Features::extract(&s, 0.0);
        f.chunks = 40;
        f.pages = 160;
        f.context_tokens = 160 * PAGE_TOKENS;
        f.query_kind = QueryClass::Summarize;
        let d = route(&auto, &f, &Signals::idle());
        assert_ne!(d.chosen.kind, ProtocolKind::LocalOnly, "{:?}", d.scores);
        // a long context under cost weighting never ships whole to the
        // frontier model either
        assert_ne!(d.chosen.kind, ProtocolKind::RemoteOnly, "{:?}", d.scores);
    }

    #[test]
    fn quality_weight_escalates_and_cost_weight_descends() {
        let s = sample("health", 0);
        let f = Features::extract(&s, 0.3);
        let quality_first = AutoSpec {
            weights: RouteWeights::parse("0:0:1").unwrap(),
            ..AutoSpec::default()
        };
        let dq = route(&quality_first, &f, &Signals::idle());
        assert_eq!(dq.chosen.kind, ProtocolKind::RemoteOnly, "{:?}", dq.scores);
        let cost_first = AutoSpec {
            weights: RouteWeights::parse("0:1:0").unwrap(),
            ..AutoSpec::default()
        };
        let dc = route(&cost_first, &f, &Signals::idle());
        assert_eq!(dc.chosen.kind, ProtocolKind::LocalOnly, "{:?}", dc.scores);
    }

    #[test]
    fn scheduler_pressure_pushes_local_heavy_rungs_off_the_box() {
        let auto = AutoSpec {
            weights: RouteWeights::parse("8:1:1").unwrap(),
            ..AutoSpec::default()
        };
        let s = sample("finance", 0);
        let mut f = Features::extract(&s, 0.9);
        f.chunks = 6;
        let calm = route(&auto, &f, &Signals::idle());
        let slammed = Signals {
            queue_depth: 4096,
            high_water: true,
            saturated: 3,
            ..Signals::idle()
        };
        let hot = route(&auto, &f, &slammed);
        let lat = |d: &RouteDecision, k: ProtocolKind| {
            d.scores
                .iter()
                .find(|c| c.kind == k)
                .map(|c| c.latency)
                .unwrap()
        };
        // pressure inflates local-pass latency estimates but not
        // remote-only's, so the ranking shifts toward remote rungs
        assert!(lat(&hot, ProtocolKind::LocalOnly) > lat(&calm, ProtocolKind::LocalOnly));
        assert_eq!(
            lat(&hot, ProtocolKind::RemoteOnly),
            lat(&calm, ProtocolKind::RemoteOnly)
        );
        assert!(hot.scores.iter().any(|c| c.kind == ProtocolKind::RemoteOnly));
    }

    #[test]
    fn allowed_subset_restricts_the_ladder() {
        let auto = AutoSpec::parse(r#"{"kind":"auto","allowed":["minions"]}"#).unwrap();
        let s = sample("finance", 1);
        let f = Features::extract(&s, 0.99);
        let d = route(&auto, &f, &Signals::idle());
        assert_eq!(d.chosen.kind, ProtocolKind::Minions);
        assert_eq!(d.scores.len(), 1);
    }

    #[test]
    fn decision_json_is_replay_stable_and_self_describing() {
        let auto = AutoSpec::default();
        let s = sample("health", 2);
        let f = Features::extract(&s, 0.42);
        let d = route(&auto, &f, &Signals::idle());
        let j = d.to_json();
        let bytes = j.to_string();
        // parse → re-encode reproduces the bytes (hex-bit floats)
        let reparsed = Json::parse(&bytes).unwrap();
        assert_eq!(reparsed.to_string(), bytes);
        // the chosen spec round-trips through the replay helper
        let spec = routed_spec(&reparsed).unwrap();
        assert_eq!(spec, d.chosen);
        assert_eq!(
            reparsed.get("fingerprint").and_then(Json::as_str),
            Some(format!("{:016x}", d.chosen.fingerprint()).as_str())
        );
        assert!(routed_summary(&reparsed).starts_with("auto->"));
        // same inputs, same bytes: the pure core is deterministic
        let again = route(&auto, &f, &Signals::idle());
        assert_eq!(again.to_json().to_string(), bytes);
    }

    #[test]
    fn real_samples_route_end_to_end_without_a_probe() {
        // every dataset's shape features produce a valid decision even
        // at confidence 0 (probe unavailable)
        let auto = AutoSpec::default();
        for name in data::DATASETS {
            let ds = data::generate(name, 3, 13);
            for s in &ds.samples {
                let f = Features::extract(s, 0.0);
                let d = route(&auto, &f, &Signals::idle());
                assert!(auto.allowed.contains(&d.chosen.kind));
                assert!(d.chosen.validate().is_ok());
            }
        }
    }

    #[test]
    fn schema_names_every_auto_field() {
        let schema = auto_schema_json();
        for key in ["kind", "local", "remote", "route_weights", "probe_budget", "allowed"] {
            let f = schema.get(key).unwrap_or_else(|| panic!("missing {key}"));
            assert!(f.get("help").is_some() && f.get("default").is_some());
        }
    }
}
