//! # Minions
//!
//! A full-system reproduction of *"Minions: Cost-efficient Collaboration
//! Between On-device and Cloud Language Models"* (Narayan, Biderman,
//! Eyuboglu et al., 2025) as a three-layer Rust + JAX + Pallas serving
//! stack (AOT via XLA/PJRT).
//!
//! - **L3 (this crate)**: the paper's contribution — the `Minion` and
//!   `MinionS` local↔remote communication protocols, job decomposition via
//!   remote-generated code (the MinionScript DSL), the local job
//!   scheduler/batcher, cost accounting, datasets, RAG baselines, and a
//!   serving front-end. Python never runs on the request path.
//! - **L2/L1 (build-time Python)**: the model compute graph and Pallas
//!   kernels, lowered once to HLO text (`make artifacts`) and executed
//!   here through the PJRT CPU client.
//!
//! See DESIGN.md for the system inventory and the per-experiment index,
//! and EXPERIMENTS.md for reproduction results.

// The whole stack is safe Rust (the PJRT boundary lives behind a
// subprocess, not FFI); forbid keeps it that way.
#![forbid(unsafe_code)]

pub mod cache;
pub mod cost;
pub mod data;
pub mod dsl;
pub mod eval;
pub mod exp;
pub mod latency;
pub mod lint;
pub mod perf;
pub mod protocol;
pub mod rag;
pub mod router;
pub mod sched;
pub mod server;
pub mod model;
pub mod util;
pub mod vocab;
pub mod runtime;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
