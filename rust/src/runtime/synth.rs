//! Synthetic artifact generation: a self-contained `manifest.json` +
//! MNW1 weight files + stub `.hlo` texts, written to any directory.
//!
//! The real artifacts come out of the Python AOT pass (`make
//! artifacts`), which CI and fresh checkouts don't run. The offline
//! engine only ever reads the manifest and the weight files — the HLO
//! stubs exist to satisfy path checks — so a synthetic set is enough to
//! exercise the full engine/bench stack: deterministic weights from
//! [`crate::util::rng::Rng`], real `[VOCAB, d]` embedding tables, and
//! the same descending window weights shape the compiler emits.
//!
//! Used by the engine-pool tests and by `minions bench hotpath --json`
//! / `cargo bench --bench runtime_hotpath -- --json` when no real
//! artifact directory is present.

use super::manifest::Manifest;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::vocab::{BATCH, CHUNK, QLEN, VOCAB, WINDOW};
use anyhow::{Context, Result};
use std::path::Path;

/// Descending positional weights normalized to sum 1 — the same shape
/// the real artifacts carry (e.g. `[0.5, 0.3, 0.2]` for WINDOW=3).
pub fn window_weights() -> Vec<f32> {
    let total: f32 = (1..=WINDOW).map(|j| j as f32).sum();
    (0..WINDOW).map(|j| (WINDOW - j) as f32 / total).collect()
}

/// Write a complete synthetic artifact set under `dir` and load it back
/// through the ordinary [`Manifest::load`] path. `ds` lists the score
/// capacities; `embed_d` selects the embed module's width (its weight
/// file is added if not already in `ds`). Weights are deterministic in
/// `seed`, so two calls with the same arguments produce byte-identical
/// files.
pub fn write_synthetic_artifacts(
    dir: &Path,
    ds: &[usize],
    embed_d: usize,
    seed: u64,
) -> Result<Manifest> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating artifact dir {}", dir.display()))?;
    let mut all: Vec<usize> = ds.to_vec();
    all.push(embed_d);
    all.sort_unstable();
    all.dedup();

    let wpos = window_weights();
    for &d in &all {
        let mut rng = Rng::seed_from(seed ^ (d as u64).wrapping_mul(0x9e3779b97f4a7c15));
        let emb: Vec<f32> = (0..VOCAB * d)
            .map(|_| (rng.f64() * 0.2 - 0.1) as f32)
            .collect();
        let mut buf = Vec::with_capacity(emb.len() * 4 + 128);
        buf.extend_from_slice(b"MNW1");
        buf.extend_from_slice(&2u32.to_le_bytes());
        push_tensor(&mut buf, "emb", &[VOCAB, d], &emb);
        push_tensor(&mut buf, "wpos", &[WINDOW], &wpos);
        let wname = format!("weights_d{d}.mnw");
        std::fs::write(dir.join(&wname), &buf)
            .with_context(|| format!("writing {wname}"))?;
    }
    let stub = "// synthetic HLO stub — the offline engine executes the native kernel\n";
    for &d in ds {
        std::fs::write(dir.join(format!("score_d{d}.hlo")), stub)
            .with_context(|| format!("writing score_d{d}.hlo"))?;
    }
    std::fs::write(dir.join("embed.hlo"), stub).context("writing embed.hlo")?;

    let manifest = manifest_json(ds, embed_d, &wpos, &all);
    std::fs::write(dir.join("manifest.json"), manifest.to_string())
        .context("writing manifest.json")?;
    Manifest::load(dir)
}

fn push_tensor(buf: &mut Vec<u8>, name: &str, dims: &[usize], data: &[f32]) {
    buf.extend_from_slice(&(name.len() as u16).to_le_bytes());
    buf.extend_from_slice(name.as_bytes());
    buf.push(0); // dtype f32
    buf.push(dims.len() as u8);
    for &d in dims {
        buf.extend_from_slice(&(d as u64).to_le_bytes());
    }
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn io(name: &str, shape: &[usize], dtype: &str) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        (
            "shape",
            Json::Arr(shape.iter().map(|&x| Json::num(x as f64)).collect()),
        ),
        ("dtype", Json::str(dtype)),
    ])
}

fn manifest_json(ds: &[usize], embed_d: usize, wpos: &[f32], all: &[usize]) -> Json {
    let mut modules: Vec<Json> = ds
        .iter()
        .map(|&d| {
            Json::obj(vec![
                ("name", Json::str(format!("score_d{d}"))),
                ("kind", Json::str("score")),
                ("file", Json::str(format!("score_d{d}.hlo"))),
                ("d", Json::num(d as f64)),
                ("batch", Json::num(BATCH as f64)),
                ("chunk", Json::num(CHUNK as f64)),
                ("weights", Json::str(format!("weights_d{d}.mnw"))),
                (
                    "inputs",
                    Json::Arr(vec![
                        io("emb", &[VOCAB, d], "f32"),
                        io("wpos", &[WINDOW], "f32"),
                        io("q_tokens", &[BATCH, QLEN], "s32"),
                        io("q_weights", &[BATCH, QLEN], "f32"),
                        io("c_tokens", &[BATCH, CHUNK], "s32"),
                        io("c_mask", &[BATCH, CHUNK], "f32"),
                    ]),
                ),
                (
                    "outputs",
                    Json::Arr(vec![
                        io("scores", &[BATCH, CHUNK], "f32"),
                        io("lse", &[BATCH], "f32"),
                    ]),
                ),
            ])
        })
        .collect();
    modules.push(Json::obj(vec![
        ("name", Json::str(format!("embed_d{embed_d}"))),
        ("kind", Json::str("embed")),
        ("file", Json::str("embed.hlo")),
        ("d", Json::num(embed_d as f64)),
        ("batch", Json::num(BATCH as f64)),
        ("chunk", Json::num(CHUNK as f64)),
        ("weights", Json::str(format!("weights_d{embed_d}.mnw"))),
        (
            "inputs",
            Json::Arr(vec![
                io("emb", &[VOCAB, embed_d], "f32"),
                io("c_tokens", &[BATCH, CHUNK], "s32"),
                io("c_mask", &[BATCH, CHUNK], "f32"),
            ]),
        ),
        (
            "outputs",
            Json::Arr(vec![io("chunk_emb", &[BATCH, embed_d], "f32")]),
        ),
    ]));
    let weights: Vec<Json> = all
        .iter()
        .map(|&d| {
            Json::obj(vec![
                ("file", Json::str(format!("weights_d{d}.mnw"))),
                ("d", Json::num(d as f64)),
                (
                    "wpos",
                    Json::Arr(wpos.iter().map(|&w| Json::num(w as f64)).collect()),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("format", Json::str("minions-artifacts-v1")),
        ("vocab", Json::num(VOCAB as f64)),
        ("qlen", Json::num(QLEN as f64)),
        ("window", Json::num(WINDOW as f64)),
        ("batch", Json::num(BATCH as f64)),
        ("chunk", Json::num(CHUNK as f64)),
        ("modules", Json::Arr(modules)),
        ("weights", Json::Arr(weights)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{EmbedRequest, NativeBackend, ScoreRequest};

    #[test]
    fn synthetic_artifacts_load_and_score() {
        let tmp = std::env::temp_dir().join(format!("minions-synth-{}", std::process::id()));
        let m = write_synthetic_artifacts(&tmp, &[64], 64, 7).unwrap();
        assert_eq!(m.capacities(), vec![64]);
        assert_eq!(m.wpos(64).unwrap().len(), WINDOW);

        let backend = NativeBackend::new(m).unwrap();
        let req = ScoreRequest {
            d: 64,
            q_tokens: vec![1; BATCH * QLEN],
            q_weights: vec![0.5; BATCH * QLEN],
            c_tokens: (0..BATCH * CHUNK).map(|i| (i % VOCAB) as i32).collect(),
            c_mask: vec![1.0; BATCH * CHUNK],
        };
        let resp = backend.score(&req).unwrap();
        assert_eq!(resp.scores.len(), BATCH * CHUNK);
        assert!(resp.lse.iter().all(|l| l.is_finite()));

        let emb = backend
            .embed(&EmbedRequest {
                c_tokens: req.c_tokens.clone(),
                c_mask: req.c_mask.clone(),
            })
            .unwrap();
        assert_eq!(emb.len(), BATCH * 64);

        // determinism: a second write produces byte-identical weights
        let tmp2 = std::env::temp_dir().join(format!("minions-synth2-{}", std::process::id()));
        write_synthetic_artifacts(&tmp2, &[64], 64, 7).unwrap();
        let a = std::fs::read(tmp.join("weights_d64.mnw")).unwrap();
        let b = std::fs::read(tmp2.join("weights_d64.mnw")).unwrap();
        assert_eq!(a, b);
        std::fs::remove_dir_all(&tmp).ok();
        std::fs::remove_dir_all(&tmp2).ok();
    }
}
