//! `artifacts/manifest.json` loader — the contract between the build-time
//! Python AOT pass and the Rust runtime. Validates the shared constants
//! (vocab size, chunk geometry) so a drifted rebuild fails fast.

use crate::util::json::Json;
use crate::vocab;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct IoDecl {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ModuleSpec {
    pub name: String,
    pub kind: String, // "score" | "embed"
    pub file: PathBuf,
    pub d: usize,
    pub batch: usize,
    pub chunk: usize,
    pub weights: PathBuf,
    pub inputs: Vec<IoDecl>,
    pub outputs: Vec<IoDecl>,
}

#[derive(Clone, Debug)]
pub struct WeightEntry {
    pub file: PathBuf,
    pub d: usize,
    /// window position weights (the positional-acuity capability knob);
    /// duplicated here from the weight file so the coordinator can build
    /// query weight vectors without loading the full embedding table
    pub wpos: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub vocab: usize,
    pub qlen: usize,
    pub window: usize,
    pub batch: usize,
    pub chunk: usize,
    pub modules: Vec<ModuleSpec>,
    pub weights: Vec<WeightEntry>,
}

fn io_decls(v: &Json) -> Result<Vec<IoDecl>> {
    v.as_arr()
        .context("expected array of io decls")?
        .iter()
        .map(|d| {
            Ok(IoDecl {
                name: d.get("name").and_then(Json::as_str).context("io name")?.to_string(),
                shape: d
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("io shape")?
                    .iter()
                    .map(|x| x.as_f64().map(|f| f as usize).context("shape dim"))
                    .collect::<Result<_>>()?,
                dtype: d.get("dtype").and_then(Json::as_str).context("io dtype")?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;

        if root.get("format").and_then(Json::as_str) != Some("minions-artifacts-v1") {
            bail!("unknown manifest format");
        }
        let num = |k: &str| -> Result<usize> {
            root.get(k)
                .and_then(Json::as_f64)
                .map(|f| f as usize)
                .with_context(|| format!("manifest field {k}"))
        };
        let m = Manifest {
            dir: dir.clone(),
            vocab: num("vocab")?,
            qlen: num("qlen")?,
            window: num("window")?,
            batch: num("batch")?,
            chunk: num("chunk")?,
            modules: root
                .get("modules")
                .and_then(Json::as_arr)
                .context("modules")?
                .iter()
                .map(|j| {
                    Ok(ModuleSpec {
                        name: j.get("name").and_then(Json::as_str).context("name")?.into(),
                        kind: j.get("kind").and_then(Json::as_str).context("kind")?.into(),
                        file: dir.join(j.get("file").and_then(Json::as_str).context("file")?),
                        d: j.get("d").and_then(Json::as_f64).context("d")? as usize,
                        batch: j.get("batch").and_then(Json::as_f64).context("batch")? as usize,
                        chunk: j.get("chunk").and_then(Json::as_f64).context("chunk")? as usize,
                        weights: dir
                            .join(j.get("weights").and_then(Json::as_str).context("weights")?),
                        inputs: io_decls(j.get("inputs").context("inputs")?)?,
                        outputs: io_decls(j.get("outputs").context("outputs")?)?,
                    })
                })
                .collect::<Result<_>>()?,
            weights: root
                .get("weights")
                .and_then(Json::as_arr)
                .context("weights")?
                .iter()
                .map(|j| {
                    Ok(WeightEntry {
                        file: dir.join(j.get("file").and_then(Json::as_str).context("w file")?),
                        d: j.get("d").and_then(Json::as_f64).context("w d")? as usize,
                        wpos: j
                            .get("wpos")
                            .and_then(Json::as_arr)
                            .context("w wpos")?
                            .iter()
                            .map(|x| x.as_f64().map(|f| f as f32).context("wpos item"))
                            .collect::<Result<_>>()?,
                    })
                })
                .collect::<Result<_>>()?,
        };

        // Cross-language constant check (DESIGN.md: fail fast on drift).
        if m.vocab != vocab::VOCAB
            || m.qlen != vocab::QLEN
            || m.window != vocab::WINDOW
            || m.batch != vocab::BATCH
            || m.chunk != vocab::CHUNK
        {
            bail!(
                "manifest constants drifted from rust vocab module: \
                 vocab={} qlen={} window={} batch={} chunk={}",
                m.vocab,
                m.qlen,
                m.window,
                m.batch,
                m.chunk
            );
        }
        Ok(m)
    }

    pub fn score_module(&self, d: usize) -> Result<&ModuleSpec> {
        self.modules
            .iter()
            .find(|m| m.kind == "score" && m.d == d)
            .with_context(|| format!("no score module with d={d} in manifest"))
    }

    pub fn embed_module(&self) -> Result<&ModuleSpec> {
        self.modules
            .iter()
            .find(|m| m.kind == "embed")
            .context("no embed module in manifest")
    }

    /// Window position weights for capacity `d`.
    pub fn wpos(&self, d: usize) -> Result<&[f32]> {
        self.weights
            .iter()
            .find(|w| w.d == d)
            .map(|w| w.wpos.as_slice())
            .with_context(|| format!("no weight entry with d={d}"))
    }

    pub fn capacities(&self) -> Vec<usize> {
        let mut ds: Vec<usize> = self
            .modules
            .iter()
            .filter(|m| m.kind == "score")
            .map(|m| m.d)
            .collect();
        ds.sort();
        ds.dedup();
        ds
    }
}

impl Manifest {
    /// Test-support constructor: a manifest carrying only `wpos` weight
    /// entries (no modules, no files on disk), so model wrappers can be
    /// built against stub backends without compiled artifacts. Every
    /// listed capacity shares the same `wpos` vector.
    #[doc(hidden)]
    pub fn stub_for_tests(capacities: &[usize], wpos: Vec<f32>) -> Manifest {
        Manifest {
            dir: PathBuf::new(),
            vocab: vocab::VOCAB,
            qlen: vocab::QLEN,
            window: vocab::WINDOW,
            batch: vocab::BATCH,
            chunk: vocab::CHUNK,
            modules: Vec::new(),
            weights: capacities
                .iter()
                .map(|d| WeightEntry {
                    file: PathBuf::new(),
                    d: *d,
                    wpos: wpos.clone(),
                })
                .collect(),
        }
    }
}

/// Default artifact dir: `$MINIONS_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("MINIONS_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // Walk up from cwd looking for artifacts/manifest.json (works from
    // target/, examples, and the repo root).
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_when_present() {
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.modules.is_empty());
        assert!(m.score_module(128).is_ok());
        assert!(m.embed_module().is_ok());
        let caps = m.capacities();
        assert!(caps.contains(&64) && caps.contains(&1024));
        for spec in &m.modules {
            assert!(spec.file.exists(), "missing {}", spec.file.display());
            assert!(spec.weights.exists());
        }
    }

    #[test]
    fn rejects_bad_format() {
        let tmp = std::env::temp_dir().join(format!("minions-test-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.json"), r#"{"format":"nope"}"#).unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }
}
