//! MNW1 weight-file reader (format written by `python/compile/weights.py`).
//!
//! ```text
//! magic   b"MNW1"
//! u32     n_tensors
//! per tensor:
//!     u16     name_len, name utf-8 bytes
//!     u8      dtype     (0 = f32)
//!     u8      ndim
//!     u64*    dims
//!     f32*    row-major data (little-endian)
//! ```

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

pub struct WeightFile {
    pub tensors: HashMap<String, Tensor>,
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

/// Fixed-width view of a `Cursor::take` result. The length always
/// matches by construction, so the error arm is unreachable; mapping it
/// (instead of unwrapping) keeps the parser panic-free on any input.
fn array<const N: usize>(s: &[u8]) -> Result<[u8; N]> {
    s.try_into()
        .map_err(|_| anyhow!("internal: slice width != {N}"))
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let slice = self
            .pos
            .checked_add(n)
            .and_then(|end| self.buf.get(self.pos..end));
        match slice {
            Some(s) => {
                self.pos += n;
                Ok(s)
            }
            None => bail!("truncated weight file at byte {}", self.pos),
        }
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(array(self.take(2)?)?))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(array(self.take(4)?)?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(array(self.take(8)?)?))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?.first().copied().unwrap_or_default())
    }
}

impl WeightFile {
    pub fn load(path: impl AsRef<Path>) -> Result<WeightFile> {
        let path = path.as_ref();
        let buf = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&buf).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(buf: &[u8]) -> Result<WeightFile> {
        let mut c = Cursor { buf, pos: 0 };
        if c.take(4)? != b"MNW1" {
            bail!("bad magic (expected MNW1)");
        }
        let n = c.u32()? as usize;
        let mut tensors = HashMap::with_capacity(n);
        for _ in 0..n {
            let name_len = c.u16()? as usize;
            let name = std::str::from_utf8(c.take(name_len)?)
                .context("tensor name not utf-8")?
                .to_string();
            let dtype = c.u8()?;
            if dtype != 0 {
                bail!("unsupported dtype {dtype} for tensor '{name}'");
            }
            let ndim = c.u8()? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(c.u64()? as usize);
            }
            let numel = dims
                .iter()
                .try_fold(1usize, |a, &d| a.checked_mul(d))
                .with_context(|| format!("tensor '{name}' dims overflow"))?;
            let nbytes = numel
                .checked_mul(4)
                .with_context(|| format!("tensor '{name}' size overflow"))?;
            let raw = c.take(nbytes)?;
            let mut data = Vec::with_capacity(numel);
            for chunk in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes(array(chunk)?));
            }
            tensors.insert(name, Tensor { dims, data });
        }
        if c.pos != buf.len() {
            bail!("{} trailing bytes after last tensor", buf.len() - c.pos);
        }
        Ok(WeightFile { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("weight tensor '{name}' missing"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_file() -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"MNW1");
        buf.extend_from_slice(&2u32.to_le_bytes());
        // tensor "a": [2, 3]
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.extend_from_slice(b"a");
        buf.push(0); // dtype f32
        buf.push(2); // ndim
        buf.extend_from_slice(&2u64.to_le_bytes());
        buf.extend_from_slice(&3u64.to_le_bytes());
        for i in 0..6 {
            buf.extend_from_slice(&(i as f32).to_le_bytes());
        }
        // tensor "wpos": [3]
        buf.extend_from_slice(&4u16.to_le_bytes());
        buf.extend_from_slice(b"wpos");
        buf.push(0);
        buf.push(1);
        buf.extend_from_slice(&3u64.to_le_bytes());
        for v in [0.5f32, 0.3, 0.2] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf
    }

    #[test]
    fn parses_valid_file() {
        let wf = WeightFile::parse(&sample_file()).unwrap();
        let a = wf.get("a").unwrap();
        assert_eq!(a.dims, vec![2, 3]);
        assert_eq!(a.data, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let w = wf.get("wpos").unwrap();
        assert_eq!(w.dims, vec![3]);
        assert_eq!(w.numel(), 3);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut buf = sample_file();
        buf[0] = b'X';
        assert!(WeightFile::parse(&buf).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let buf = sample_file();
        assert!(WeightFile::parse(&buf[..buf.len() - 2]).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut buf = sample_file();
        buf.push(0);
        assert!(WeightFile::parse(&buf).is_err());
    }

    #[test]
    fn missing_tensor_errors() {
        let wf = WeightFile::parse(&sample_file()).unwrap();
        assert!(wf.get("nope").is_err());
    }
}
