//! Runtime layer: PJRT artifact loading + execution (see DESIGN.md §3).
//!
//! `Backend` abstracts the scorer so the coordinator can run against the
//! real PJRT engine (production path) or the pure-Rust native oracle
//! (fast tests, cross-checks).

pub mod engine;
pub mod manifest;
pub mod native;
pub mod synth;
pub mod weights;

pub use engine::{EmbedRequest, Engine, EngineStats, ScoreRequest, ScoreResponse};
pub use manifest::{default_artifact_dir, Manifest, ModuleSpec, WeightEntry};
pub use native::{NativeBackend, PooledQueryCache};
pub use weights::{Tensor, WeightFile};

use anyhow::Result;

/// The scoring/embedding backend interface the coordinator programs to.
pub trait Backend: Send + Sync {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse>;
    fn embed(&self, req: EmbedRequest) -> Result<Vec<f32>>;
    fn name(&self) -> &'static str;
}

/// Combined hot-path statistics: engine-level dispatch counters plus the
/// dynamic batcher's row/occupancy view (the serving-efficiency headline).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    /// engine counters; `None` when the backend has no engine workers
    /// (e.g. the native oracle)
    pub engine: Option<EngineStats>,
    /// shared-batcher counters; `None` when scoring bypasses the batcher
    pub batcher: Option<crate::sched::BatcherSnapshot>,
    /// chunk-cache counters; `None` when caching is disabled
    pub cache: Option<crate::cache::CacheSnapshot>,
}

/// Engine-backed production backend. The [`Engine`] handle is a shared
/// work queue behind `Arc`s (`Send + Sync`), so requests from many
/// coordinator threads fan out to the worker pool directly.
pub struct PjrtBackend {
    engine: Engine,
}

impl PjrtBackend {
    pub fn new(engine: Engine) -> Self {
        PjrtBackend { engine }
    }

    pub fn start(manifest: Manifest, precompile: &[usize]) -> Result<Self> {
        Ok(Self::new(Engine::start(manifest, precompile)?))
    }

    /// Start with `workers` engine threads (see [`Engine::start_pool`]).
    pub fn start_pool(manifest: Manifest, precompile: &[usize], workers: usize) -> Result<Self> {
        Ok(Self::new(Engine::start_pool(manifest, precompile, workers)?))
    }

    pub fn stats(&self) -> EngineStats {
        self.engine.stats()
    }
}

impl Backend for PjrtBackend {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse> {
        self.engine.score(req)
    }

    fn embed(&self, req: EmbedRequest) -> Result<Vec<f32>> {
        self.engine.embed(req)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

impl Backend for NativeBackend {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse> {
        NativeBackend::score(self, &req)
    }

    fn embed(&self, req: EmbedRequest) -> Result<Vec<f32>> {
        NativeBackend::embed(self, &req)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}
