//! Runtime layer: PJRT artifact loading + execution (see DESIGN.md §3).
//!
//! `Backend` abstracts the scorer so the coordinator can run against the
//! real PJRT engine (production path) or the pure-Rust native oracle
//! (fast tests, cross-checks).

pub mod engine;
pub mod manifest;
pub mod native;
pub mod weights;

pub use engine::{EmbedRequest, Engine, EngineStats, ScoreRequest, ScoreResponse};
pub use manifest::{default_artifact_dir, Manifest, ModuleSpec, WeightEntry};
pub use native::NativeBackend;
pub use weights::{Tensor, WeightFile};

use anyhow::Result;
use std::sync::Mutex;

/// The scoring/embedding backend interface the coordinator programs to.
pub trait Backend: Send + Sync {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse>;
    fn embed(&self, req: EmbedRequest) -> Result<Vec<f32>>;
    fn name(&self) -> &'static str;
}

/// Combined hot-path statistics: engine-level dispatch counters plus the
/// dynamic batcher's row/occupancy view (the serving-efficiency headline).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    /// engine counters; `None` when the backend has no engine thread
    /// (e.g. the native oracle)
    pub engine: Option<EngineStats>,
    /// shared-batcher counters; `None` when scoring bypasses the batcher
    pub batcher: Option<crate::sched::BatcherSnapshot>,
    /// chunk-cache counters; `None` when caching is disabled
    pub cache: Option<crate::cache::CacheSnapshot>,
}

/// PJRT-backed production backend. `mpsc::Sender` is `!Sync`, so the
/// handle is wrapped in a mutex; actual execution happens on the engine
/// thread (requests are serialized there anyway — one CPU device).
pub struct PjrtBackend {
    engine: Mutex<Engine>,
}

impl PjrtBackend {
    pub fn new(engine: Engine) -> Self {
        PjrtBackend {
            engine: Mutex::new(engine),
        }
    }

    pub fn start(manifest: Manifest, precompile: &[usize]) -> Result<Self> {
        Ok(Self::new(Engine::start(manifest, precompile)?))
    }

    pub fn stats(&self) -> EngineStats {
        self.engine.lock().unwrap().stats()
    }
}

impl Backend for PjrtBackend {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse> {
        let engine = self.engine.lock().unwrap().clone();
        engine.score(req)
    }

    fn embed(&self, req: EmbedRequest) -> Result<Vec<f32>> {
        let engine = self.engine.lock().unwrap().clone();
        engine.embed(req)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

impl Backend for NativeBackend {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse> {
        NativeBackend::score(self, &req)
    }

    fn embed(&self, req: EmbedRequest) -> Result<Vec<f32>> {
        NativeBackend::embed(self, &req)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}
