//! Execution engine: loads scorer artifacts and serves batched
//! score/embed requests from a dedicated engine thread.
//!
//! A single engine thread owns the loaded modules and device state;
//! callers talk to it through channels via the cloneable [`Engine`]
//! handle. Two execution paths share this scaffolding:
//!
//! - **`xla-pjrt` feature** (production): HLO-text artifacts are compiled
//!   on the PJRT CPU client and weight tensors are staged on-device once
//!   at module-load time, exactly as before. Requires the external `xla`
//!   bindings crate, which is not vendored in this offline build —
//!   enabling the feature without it is a compile error by design.
//! - **default** (offline): the engine thread executes the *same math*
//!   as the pure-Rust native oracle (`runtime::native`) directly over the
//!   artifact weight files. Module "compilation" is the one-time weight
//!   load, so [`EngineStats`] keeps its meaning and the PJRT↔native
//!   equivalence tests hold trivially.

use super::manifest::Manifest;
use crate::vocab::{BATCH, CHUNK, QLEN};
use anyhow::{anyhow, bail, Context, Result};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// One batched scoring dispatch (B rows padded by the caller).
#[derive(Clone, Debug)]
pub struct ScoreRequest {
    /// capacity (embedding width) selecting the score module
    pub d: usize,
    pub q_tokens: Vec<i32>,  // [B * QLEN]
    pub q_weights: Vec<f32>, // [B * QLEN]
    pub c_tokens: Vec<i32>,  // [B * CHUNK]
    pub c_mask: Vec<f32>,    // [B * CHUNK]
}

#[derive(Clone, Debug)]
pub struct ScoreResponse {
    pub scores: Vec<f32>, // [B * CHUNK]
    pub lse: Vec<f32>,    // [B]
}

#[derive(Clone, Debug)]
pub struct EmbedRequest {
    pub c_tokens: Vec<i32>, // [B * CHUNK]
    pub c_mask: Vec<f32>,   // [B * CHUNK]
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub dispatches: u64,
    pub rows: u64,
    pub exec_secs: f64,
    pub compile_secs: f64,
}

enum Request {
    Score(ScoreRequest, mpsc::Sender<Result<ScoreResponse>>),
    Embed(EmbedRequest, mpsc::Sender<Result<Vec<f32>>>),
    Stats(mpsc::Sender<EngineStats>),
    Shutdown,
}

/// Cloneable handle to the engine thread.
#[derive(Clone)]
pub struct Engine {
    tx: mpsc::Sender<Request>,
    // joined on last drop
    join: Arc<Mutex<Option<std::thread::JoinHandle<()>>>>,
}

impl Engine {
    /// Start the engine. Modules are compiled lazily on first use unless
    /// listed in `precompile`.
    pub fn start(manifest: Manifest, precompile: &[usize]) -> Result<Engine> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let pre: Vec<usize> = precompile.to_vec();
        let join = std::thread::Builder::new()
            .name("engine".into())
            .spawn(move || engine_main(manifest, pre, rx, ready_tx))
            .context("spawning engine thread")?;
        ready_rx
            .recv()
            .context("engine thread died during startup")??;
        Ok(Engine {
            tx,
            join: Arc::new(Mutex::new(Some(join))),
        })
    }

    /// Convenience: start from the default artifact dir.
    pub fn start_default() -> Result<Engine> {
        let manifest = Manifest::load(super::manifest::default_artifact_dir())?;
        Engine::start(manifest, &[])
    }

    pub fn score(&self, req: ScoreRequest) -> Result<ScoreResponse> {
        let b = req.q_tokens.len() / QLEN;
        if req.q_tokens.len() != b * QLEN
            || req.q_weights.len() != b * QLEN
            || req.c_tokens.len() != b * CHUNK
            || req.c_mask.len() != b * CHUNK
            || b != BATCH
        {
            bail!(
                "score request shape mismatch: q={} qw={} c={} cm={} (want B={BATCH})",
                req.q_tokens.len(),
                req.q_weights.len(),
                req.c_tokens.len(),
                req.c_mask.len()
            );
        }
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request::Score(req, tx))
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    pub fn embed(&self, req: EmbedRequest) -> Result<Vec<f32>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request::Embed(req, tx))
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    pub fn stats(&self) -> EngineStats {
        let (tx, rx) = mpsc::channel();
        if self.tx.send(Request::Stats(tx)).is_err() {
            return EngineStats::default();
        }
        rx.recv().unwrap_or_default()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        if Arc::strong_count(&self.join) == 1 {
            let _ = self.tx.send(Request::Shutdown);
            if let Some(h) = self.join.lock().unwrap().take() {
                let _ = h.join();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Engine thread main loop (shared by both execution paths)
// ---------------------------------------------------------------------------

fn engine_main(
    manifest: Manifest,
    precompile: Vec<usize>,
    rx: mpsc::Receiver<Request>,
    ready_tx: mpsc::Sender<Result<()>>,
) {
    let mut state = match exec::ExecState::new(manifest) {
        Ok(s) => s,
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    for d in &precompile {
        if let Err(e) = state.ensure_score(*d) {
            let _ = ready_tx.send(Err(e));
            return;
        }
    }
    let _ = ready_tx.send(Ok(()));

    while let Ok(req) = rx.recv() {
        match req {
            Request::Score(r, reply) => {
                let res = state.run_score(r);
                let _ = reply.send(res);
            }
            Request::Embed(r, reply) => {
                let res = state.run_embed(r);
                let _ = reply.send(res);
            }
            Request::Stats(reply) => {
                let _ = reply.send(state.stats());
            }
            Request::Shutdown => break,
        }
    }
}

// ---------------------------------------------------------------------------
// Offline execution path: the native-oracle math over artifact weights
// ---------------------------------------------------------------------------

#[cfg(not(feature = "xla-pjrt"))]
mod exec {
    use super::super::native::{embed_kernel, score_kernel};
    use super::super::weights::WeightFile;
    use super::{EmbedRequest, EngineStats, Manifest, Result, ScoreRequest, ScoreResponse};
    use anyhow::bail;
    use std::collections::HashMap;
    use std::time::Instant;

    struct LoadedWeights {
        d: usize,
        emb: Vec<f32>,  // [V, d]
        wpos: Vec<f32>, // [W]
    }

    pub(super) struct ExecState {
        manifest: Manifest,
        score_weights: HashMap<usize, LoadedWeights>,
        embed_weights: Option<LoadedWeights>,
        stats: EngineStats,
    }

    impl ExecState {
        pub(super) fn new(manifest: Manifest) -> Result<ExecState> {
            Ok(ExecState {
                manifest,
                score_weights: HashMap::new(),
                embed_weights: None,
                stats: EngineStats::default(),
            })
        }

        fn load(&mut self, weights_path: &std::path::Path, d: usize) -> Result<LoadedWeights> {
            let t0 = Instant::now();
            let wf = WeightFile::load(weights_path)?;
            let emb = wf.get("emb")?;
            let wpos = wf.get("wpos")?;
            if emb.dims.len() != 2 || emb.dims[1] != d {
                bail!("emb dims {:?} inconsistent with d={d}", emb.dims);
            }
            self.stats.compile_secs += t0.elapsed().as_secs_f64();
            Ok(LoadedWeights {
                d,
                emb: emb.data.clone(),
                wpos: wpos.data.clone(),
            })
        }

        pub(super) fn ensure_score(&mut self, d: usize) -> Result<()> {
            if !self.score_weights.contains_key(&d) {
                let path = self.manifest.score_module(d)?.weights.clone();
                let w = self.load(&path, d)?;
                self.score_weights.insert(d, w);
            }
            Ok(())
        }

        fn ensure_embed(&mut self) -> Result<()> {
            if self.embed_weights.is_none() {
                let spec = self.manifest.embed_module()?;
                let (path, d) = (spec.weights.clone(), spec.d);
                self.embed_weights = Some(self.load(&path, d)?);
            }
            Ok(())
        }

        pub(super) fn run_score(&mut self, req: ScoreRequest) -> Result<ScoreResponse> {
            if req.q_tokens.len() != super::BATCH * super::QLEN
                || req.q_weights.len() != super::BATCH * super::QLEN
                || req.c_tokens.len() != super::BATCH * super::CHUNK
                || req.c_mask.len() != super::BATCH * super::CHUNK
            {
                // bail per-request instead of letting the kernel index out
                // of bounds and kill the engine thread
                bail!("score request shape mismatch");
            }
            self.ensure_score(req.d)?;
            let w = self.score_weights.get(&req.d).unwrap();
            let t0 = Instant::now();
            let resp = score_kernel(&w.emb, &w.wpos, w.d, &req);
            self.stats.dispatches += 1;
            self.stats.rows += super::BATCH as u64;
            self.stats.exec_secs += t0.elapsed().as_secs_f64();
            Ok(resp)
        }

        pub(super) fn run_embed(&mut self, req: EmbedRequest) -> Result<Vec<f32>> {
            if req.c_tokens.len() != super::BATCH * super::CHUNK
                || req.c_mask.len() != super::BATCH * super::CHUNK
            {
                bail!("embed request shape mismatch");
            }
            self.ensure_embed()?;
            let w = self.embed_weights.as_ref().unwrap();
            let t0 = Instant::now();
            let out = embed_kernel(&w.emb, w.d, &req);
            self.stats.dispatches += 1;
            self.stats.rows += super::BATCH as u64;
            self.stats.exec_secs += t0.elapsed().as_secs_f64();
            Ok(out)
        }

        pub(super) fn stats(&self) -> EngineStats {
            self.stats.clone()
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT execution path (requires the external `xla` bindings crate)
// ---------------------------------------------------------------------------

#[cfg(feature = "xla-pjrt")]
mod exec {
    use super::super::manifest::ModuleSpec;
    use super::super::weights::WeightFile;
    use super::{
        EmbedRequest, EngineStats, Manifest, Result, ScoreRequest, ScoreResponse, BATCH, CHUNK,
        QLEN,
    };
    use anyhow::{anyhow, bail};
    use std::collections::HashMap;
    use std::sync::Arc;
    use std::time::Instant;

    struct LoadedModule {
        exe: xla::PjRtLoadedExecutable,
        /// device-resident weight buffers, in input order (emb [, wpos])
        weight_bufs: Vec<xla::PjRtBuffer>,
        spec: ModuleSpec,
    }

    pub(super) struct ExecState {
        client: xla::PjRtClient,
        manifest: Manifest,
        score_modules: HashMap<usize, LoadedModule>,
        embed_module: Option<LoadedModule>,
        weight_cache: HashMap<String, Arc<WeightFile>>,
        stats: EngineStats,
    }

    impl ExecState {
        pub(super) fn new(manifest: Manifest) -> Result<ExecState> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("PjRtClient::cpu failed: {e:?}"))?;
            Ok(ExecState {
                client,
                manifest,
                score_modules: HashMap::new(),
                embed_module: None,
                weight_cache: HashMap::new(),
                stats: EngineStats::default(),
            })
        }

        fn load_module(&mut self, spec: &ModuleSpec) -> Result<LoadedModule> {
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&spec.file)
                .map_err(|e| anyhow!("loading {}: {e:?}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", spec.name))?;

            // Stage weight tensors on-device once.
            let wkey = spec.weights.to_string_lossy().to_string();
            let wf = match self.weight_cache.get(&wkey) {
                Some(wf) => Arc::clone(wf),
                None => {
                    let wf = Arc::new(WeightFile::load(&spec.weights)?);
                    self.weight_cache.insert(wkey, Arc::clone(&wf));
                    wf
                }
            };
            let mut weight_bufs = Vec::new();
            for decl in &spec.inputs {
                if decl.name == "emb" || decl.name == "wpos" {
                    let t = wf.get(&decl.name)?;
                    if t.dims != decl.shape {
                        bail!(
                            "weight '{}' shape {:?} != declared {:?}",
                            decl.name,
                            t.dims,
                            decl.shape
                        );
                    }
                    let buf = buffer_f32(&self.client, &t.data, &t.dims)
                        .map_err(|e| anyhow!("staging weight '{}': {e}", decl.name))?;
                    weight_bufs.push(buf);
                }
            }
            self.stats.compile_secs += t0.elapsed().as_secs_f64();
            Ok(LoadedModule {
                exe,
                weight_bufs,
                spec: spec.clone(),
            })
        }

        pub(super) fn ensure_score(&mut self, d: usize) -> Result<()> {
            if !self.score_modules.contains_key(&d) {
                let spec = self.manifest.score_module(d)?.clone();
                let m = self.load_module(&spec)?;
                self.score_modules.insert(d, m);
            }
            Ok(())
        }

        fn ensure_embed(&mut self) -> Result<()> {
            if self.embed_module.is_none() {
                let spec = self.manifest.embed_module()?.clone();
                self.embed_module = Some(self.load_module(&spec)?);
            }
            Ok(())
        }

        pub(super) fn run_score(&mut self, req: ScoreRequest) -> Result<ScoreResponse> {
            self.ensure_score(req.d)?;
            let b = BATCH;
            let module = self.score_modules.get(&req.d).unwrap();
            let q_tok = buffer_i32(&self.client, &req.q_tokens, &[b, QLEN])?;
            let q_w = buffer_f32(&self.client, &req.q_weights, &[b, QLEN])?;
            let c_tok = buffer_i32(&self.client, &req.c_tokens, &[b, CHUNK])?;
            let c_m = buffer_f32(&self.client, &req.c_mask, &[b, CHUNK])?;

            let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(6);
            for w in &module.weight_bufs {
                inputs.push(w);
            }
            inputs.push(&q_tok);
            inputs.push(&q_w);
            inputs.push(&c_tok);
            inputs.push(&c_m);

            let t0 = Instant::now();
            let result = module
                .exe
                .execute_b(&inputs)
                .map_err(|e| anyhow!("execute {}: {e:?}", module.spec.name))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("readback: {e:?}"))?;
            let (scores_lit, lse_lit) = out
                .to_tuple2()
                .map_err(|e| anyhow!("expected 2-tuple output: {e:?}"))?;
            let scores = scores_lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("scores readback: {e:?}"))?;
            let lse = lse_lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("lse readback: {e:?}"))?;
            self.stats.dispatches += 1;
            self.stats.rows += b as u64;
            self.stats.exec_secs += t0.elapsed().as_secs_f64();

            if scores.len() != b * CHUNK || lse.len() != b {
                bail!(
                    "unexpected output sizes: scores={} lse={}",
                    scores.len(),
                    lse.len()
                );
            }
            Ok(ScoreResponse { scores, lse })
        }

        pub(super) fn run_embed(&mut self, req: EmbedRequest) -> Result<Vec<f32>> {
            self.ensure_embed()?;
            let b = BATCH;
            if req.c_tokens.len() != b * CHUNK || req.c_mask.len() != b * CHUNK {
                bail!("embed request shape mismatch");
            }
            let module = self.embed_module.as_ref().unwrap();
            let c_tok = buffer_i32(&self.client, &req.c_tokens, &[b, CHUNK])?;
            let c_m = buffer_f32(&self.client, &req.c_mask, &[b, CHUNK])?;
            let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
            for w in &module.weight_bufs {
                inputs.push(w);
            }
            inputs.push(&c_tok);
            inputs.push(&c_m);
            let t0 = Instant::now();
            let result = module
                .exe
                .execute_b(&inputs)
                .map_err(|e| anyhow!("execute embed: {e:?}"))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("readback: {e:?}"))?;
            let emb_lit = out
                .to_tuple1()
                .map_err(|e| anyhow!("expected 1-tuple output: {e:?}"))?;
            let emb = emb_lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("embed readback: {e:?}"))?;
            self.stats.dispatches += 1;
            self.stats.rows += b as u64;
            self.stats.exec_secs += t0.elapsed().as_secs_f64();
            Ok(emb)
        }

        pub(super) fn stats(&self) -> EngineStats {
            self.stats.clone()
        }
    }

    fn buffer_f32(
        client: &xla::PjRtClient,
        data: &[f32],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("staging f32 buffer: {e:?}"))
    }

    fn buffer_i32(
        client: &xla::PjRtClient,
        data: &[i32],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("staging i32 buffer: {e:?}"))
    }
}
