//! PJRT execution engine: loads HLO-text artifacts, compiles them on the
//! CPU PJRT client, and serves batched score/embed requests.
//!
//! The `xla` crate's handles are not `Send`, so a dedicated engine thread
//! owns the client, the compiled executables, and the device-resident
//! weight buffers; callers talk to it through channels via the cloneable
//! [`Engine`] handle. Weight tensors (up to 32 MB for d=1024) are
//! transferred to the device once at module-load time and reused as
//! `PjRtBuffer`s on every dispatch — only the small per-request token
//! tensors cross the host/device boundary on the hot path.

use super::manifest::{Manifest, ModuleSpec};
use super::weights::WeightFile;
use crate::vocab::{BATCH, CHUNK, QLEN};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One batched scoring dispatch (B rows padded by the caller).
#[derive(Clone, Debug)]
pub struct ScoreRequest {
    /// capacity (embedding width) selecting the score module
    pub d: usize,
    pub q_tokens: Vec<i32>,  // [B * QLEN]
    pub q_weights: Vec<f32>, // [B * QLEN]
    pub c_tokens: Vec<i32>,  // [B * CHUNK]
    pub c_mask: Vec<f32>,    // [B * CHUNK]
}

#[derive(Clone, Debug)]
pub struct ScoreResponse {
    pub scores: Vec<f32>, // [B * CHUNK]
    pub lse: Vec<f32>,    // [B]
}

#[derive(Clone, Debug)]
pub struct EmbedRequest {
    pub c_tokens: Vec<i32>, // [B * CHUNK]
    pub c_mask: Vec<f32>,   // [B * CHUNK]
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub dispatches: u64,
    pub rows: u64,
    pub exec_secs: f64,
    pub compile_secs: f64,
}

enum Request {
    Score(ScoreRequest, mpsc::Sender<Result<ScoreResponse>>),
    Embed(EmbedRequest, mpsc::Sender<Result<Vec<f32>>>),
    Stats(mpsc::Sender<EngineStats>),
    Shutdown,
}

/// Cloneable handle to the engine thread.
#[derive(Clone)]
pub struct Engine {
    tx: mpsc::Sender<Request>,
    // joined on last drop
    join: Arc<Mutex<Option<std::thread::JoinHandle<()>>>>,
}

impl Engine {
    /// Start the engine. Modules are compiled lazily on first use unless
    /// listed in `precompile`.
    pub fn start(manifest: Manifest, precompile: &[usize]) -> Result<Engine> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let pre: Vec<usize> = precompile.to_vec();
        let join = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || engine_main(manifest, pre, rx, ready_tx))
            .context("spawning engine thread")?;
        ready_rx
            .recv()
            .context("engine thread died during startup")??;
        Ok(Engine {
            tx,
            join: Arc::new(Mutex::new(Some(join))),
        })
    }

    /// Convenience: start from the default artifact dir.
    pub fn start_default() -> Result<Engine> {
        let manifest = Manifest::load(super::manifest::default_artifact_dir())?;
        Engine::start(manifest, &[])
    }

    pub fn score(&self, req: ScoreRequest) -> Result<ScoreResponse> {
        let b = req.q_tokens.len() / QLEN;
        if req.q_tokens.len() != b * QLEN
            || req.q_weights.len() != b * QLEN
            || req.c_tokens.len() != b * CHUNK
            || req.c_mask.len() != b * CHUNK
            || b != BATCH
        {
            bail!(
                "score request shape mismatch: q={} qw={} c={} cm={} (want B={BATCH})",
                req.q_tokens.len(),
                req.q_weights.len(),
                req.c_tokens.len(),
                req.c_mask.len()
            );
        }
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request::Score(req, tx))
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    pub fn embed(&self, req: EmbedRequest) -> Result<Vec<f32>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request::Embed(req, tx))
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    pub fn stats(&self) -> EngineStats {
        let (tx, rx) = mpsc::channel();
        if self.tx.send(Request::Stats(tx)).is_err() {
            return EngineStats::default();
        }
        rx.recv().unwrap_or_default()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        if Arc::strong_count(&self.join) == 1 {
            let _ = self.tx.send(Request::Shutdown);
            if let Some(h) = self.join.lock().unwrap().take() {
                let _ = h.join();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Engine thread internals
// ---------------------------------------------------------------------------

struct LoadedModule {
    exe: xla::PjRtLoadedExecutable,
    /// device-resident weight buffers, in input order (emb [, wpos])
    weight_bufs: Vec<xla::PjRtBuffer>,
    spec: ModuleSpec,
}

struct EngineState {
    client: xla::PjRtClient,
    manifest: Manifest,
    score_modules: HashMap<usize, LoadedModule>,
    embed_module: Option<LoadedModule>,
    weight_cache: HashMap<String, Arc<WeightFile>>,
    stats: EngineStats,
}

fn engine_main(
    manifest: Manifest,
    precompile: Vec<usize>,
    rx: mpsc::Receiver<Request>,
    ready_tx: mpsc::Sender<Result<()>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            let _ = ready_tx.send(Err(anyhow!("PjRtClient::cpu failed: {e:?}")));
            return;
        }
    };
    log::info!(
        "pjrt engine up: platform={} devices={}",
        client.platform_name(),
        client.device_count()
    );
    let mut state = EngineState {
        client,
        manifest,
        score_modules: HashMap::new(),
        embed_module: None,
        weight_cache: HashMap::new(),
        stats: EngineStats::default(),
    };
    for d in &precompile {
        if let Err(e) = state.ensure_score(*d) {
            let _ = ready_tx.send(Err(e));
            return;
        }
    }
    let _ = ready_tx.send(Ok(()));

    while let Ok(req) = rx.recv() {
        match req {
            Request::Score(r, reply) => {
                let res = state.run_score(r);
                let _ = reply.send(res);
            }
            Request::Embed(r, reply) => {
                let res = state.run_embed(r);
                let _ = reply.send(res);
            }
            Request::Stats(reply) => {
                let _ = reply.send(state.stats.clone());
            }
            Request::Shutdown => break,
        }
    }
}

impl EngineState {
    fn load_module(&mut self, spec: &ModuleSpec) -> Result<LoadedModule> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .map_err(|e| anyhow!("loading {}: {e:?}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", spec.name))?;

        // Stage weight tensors on-device once.
        let wkey = spec.weights.to_string_lossy().to_string();
        let wf = match self.weight_cache.get(&wkey) {
            Some(wf) => Arc::clone(wf),
            None => {
                let wf = Arc::new(WeightFile::load(&spec.weights)?);
                self.weight_cache.insert(wkey, Arc::clone(&wf));
                wf
            }
        };
        let mut weight_bufs = Vec::new();
        for decl in &spec.inputs {
            if decl.name == "emb" || decl.name == "wpos" {
                let t = wf.get(&decl.name)?;
                if t.dims != decl.shape {
                    bail!(
                        "weight '{}' shape {:?} != declared {:?}",
                        decl.name,
                        t.dims,
                        decl.shape
                    );
                }
                let buf = buffer_f32(&self.client, &t.data, &t.dims)
                    .map_err(|e| anyhow!("staging weight '{}': {e}", decl.name))?;
                weight_bufs.push(buf);
            }
        }
        self.stats.compile_secs += t0.elapsed().as_secs_f64();
        log::info!(
            "compiled module {} in {:.2}s",
            spec.name,
            t0.elapsed().as_secs_f64()
        );
        Ok(LoadedModule {
            exe,
            weight_bufs,
            spec: spec.clone(),
        })
    }

    fn ensure_score(&mut self, d: usize) -> Result<()> {
        if !self.score_modules.contains_key(&d) {
            let spec = self.manifest.score_module(d)?.clone();
            let m = self.load_module(&spec)?;
            self.score_modules.insert(d, m);
        }
        Ok(())
    }

    fn ensure_embed(&mut self) -> Result<()> {
        if self.embed_module.is_none() {
            let spec = self.manifest.embed_module()?.clone();
            self.embed_module = Some(self.load_module(&spec)?);
        }
        Ok(())
    }

    fn run_score(&mut self, req: ScoreRequest) -> Result<ScoreResponse> {
        self.ensure_score(req.d)?;
        let b = BATCH;
        let module = self.score_modules.get(&req.d).unwrap();
        let q_tok = buffer_i32(&self.client, &req.q_tokens, &[b, QLEN])?;
        let q_w = buffer_f32(&self.client, &req.q_weights, &[b, QLEN])?;
        let c_tok = buffer_i32(&self.client, &req.c_tokens, &[b, CHUNK])?;
        let c_m = buffer_f32(&self.client, &req.c_mask, &[b, CHUNK])?;

        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(6);
        for w in &module.weight_bufs {
            inputs.push(w);
        }
        inputs.push(&q_tok);
        inputs.push(&q_w);
        inputs.push(&c_tok);
        inputs.push(&c_m);

        let t0 = Instant::now();
        let result = module
            .exe
            .execute_b(&inputs)
            .map_err(|e| anyhow!("execute {}: {e:?}", module.spec.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("readback: {e:?}"))?;
        let (scores_lit, lse_lit) = out
            .to_tuple2()
            .map_err(|e| anyhow!("expected 2-tuple output: {e:?}"))?;
        let scores = scores_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("scores readback: {e:?}"))?;
        let lse = lse_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("lse readback: {e:?}"))?;
        self.stats.dispatches += 1;
        self.stats.rows += b as u64;
        self.stats.exec_secs += t0.elapsed().as_secs_f64();

        if scores.len() != b * CHUNK || lse.len() != b {
            bail!(
                "unexpected output sizes: scores={} lse={}",
                scores.len(),
                lse.len()
            );
        }
        Ok(ScoreResponse { scores, lse })
    }

    fn run_embed(&mut self, req: EmbedRequest) -> Result<Vec<f32>> {
        self.ensure_embed()?;
        let b = BATCH;
        if req.c_tokens.len() != b * CHUNK || req.c_mask.len() != b * CHUNK {
            bail!("embed request shape mismatch");
        }
        let module = self.embed_module.as_ref().unwrap();
        let c_tok = buffer_i32(&self.client, &req.c_tokens, &[b, CHUNK])?;
        let c_m = buffer_f32(&self.client, &req.c_mask, &[b, CHUNK])?;
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
        for w in &module.weight_bufs {
            inputs.push(w);
        }
        inputs.push(&c_tok);
        inputs.push(&c_m);
        let t0 = Instant::now();
        let result = module
            .exe
            .execute_b(&inputs)
            .map_err(|e| anyhow!("execute embed: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("readback: {e:?}"))?;
        let emb_lit = out
            .to_tuple1()
            .map_err(|e| anyhow!("expected 1-tuple output: {e:?}"))?;
        let emb = emb_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("embed readback: {e:?}"))?;
        self.stats.dispatches += 1;
        self.stats.rows += b as u64;
        self.stats.exec_secs += t0.elapsed().as_secs_f64();
        Ok(emb)
    }
}

fn buffer_f32(client: &xla::PjRtClient, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer(data, dims, None)
        .map_err(|e| anyhow!("staging f32 buffer: {e:?}"))
}

fn buffer_i32(client: &xla::PjRtClient, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer(data, dims, None)
        .map_err(|e| anyhow!("staging i32 buffer: {e:?}"))
}
