//! Execution engine: loads scorer artifacts and serves batched
//! score/embed requests from a pool of engine worker threads.
//!
//! A shared work queue feeds `--engine-threads N` workers; callers talk
//! to the pool through the cloneable [`Engine`] handle and get replies
//! over per-request channels. Weights are loaded once and shared across
//! workers via `Arc`, so the pool costs one copy of each embedding
//! table regardless of width. Each response depends only on its request
//! and the (immutable) weights, so parallel execution is trivially
//! deterministic — see DESIGN.md §11. Two execution paths share this
//! scaffolding:
//!
//! - **`xla-pjrt` feature** (production): HLO-text artifacts are compiled
//!   on the PJRT CPU client and weight tensors are staged on-device once
//!   at module-load time. Requires the external `xla` bindings crate,
//!   which is not vendored in this offline build — enabling the feature
//!   without it is a compile error by design. Device state lives behind
//!   one mutex, so extra workers add queueing, not parallelism, here.
//! - **default** (offline): workers execute the *same math* as the
//!   pure-Rust native oracle (`runtime::native`) directly over the
//!   artifact weight files. Module "compilation" is the one-time weight
//!   load, so [`EngineStats`] keeps its meaning and the PJRT↔native
//!   equivalence tests hold trivially.

use super::manifest::Manifest;
use super::native::{PooledQueryCache, DEFAULT_POOLED_QUERY_CAP};
use crate::util::sync::{cv_wait, unpoisoned};
use crate::vocab::{BATCH, CHUNK, QLEN, VOCAB};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex};

/// One batched scoring dispatch (B rows padded by the caller).
#[derive(Clone, Debug)]
pub struct ScoreRequest {
    /// capacity (embedding width) selecting the score module
    pub d: usize,
    pub q_tokens: Vec<i32>,  // [B * QLEN]
    pub q_weights: Vec<f32>, // [B * QLEN]
    pub c_tokens: Vec<i32>,  // [B * CHUNK]
    pub c_mask: Vec<f32>,    // [B * CHUNK]
}

impl ScoreRequest {
    /// Shape and token-range check, done once at the serving surface
    /// ([`Engine::score`] / `NativeBackend::score`) so the kernels and
    /// per-exec paths never re-validate.
    pub fn validate(&self) -> Result<()> {
        if self.q_tokens.len() != BATCH * QLEN
            || self.q_weights.len() != BATCH * QLEN
            || self.c_tokens.len() != BATCH * CHUNK
            || self.c_mask.len() != BATCH * CHUNK
        {
            bail!(
                "score request shape mismatch: q={} qw={} c={} cm={} (want B={BATCH})",
                self.q_tokens.len(),
                self.q_weights.len(),
                self.c_tokens.len(),
                self.c_mask.len()
            );
        }
        check_tokens(&self.q_tokens)?;
        check_tokens(&self.c_tokens)
    }
}

#[derive(Clone, Debug)]
pub struct ScoreResponse {
    pub scores: Vec<f32>, // [B * CHUNK]
    pub lse: Vec<f32>,    // [B]
}

#[derive(Clone, Debug)]
pub struct EmbedRequest {
    pub c_tokens: Vec<i32>, // [B * CHUNK]
    pub c_mask: Vec<f32>,   // [B * CHUNK]
}

impl EmbedRequest {
    /// Shape and token-range check (see [`ScoreRequest::validate`]).
    pub fn validate(&self) -> Result<()> {
        if self.c_tokens.len() != BATCH * CHUNK || self.c_mask.len() != BATCH * CHUNK {
            bail!(
                "embed request shape mismatch: c={} cm={} (want B={BATCH})",
                self.c_tokens.len(),
                self.c_mask.len()
            );
        }
        check_tokens(&self.c_tokens)
    }
}

fn check_tokens(toks: &[i32]) -> Result<()> {
    match toks.iter().find(|&&t| t < 0 || t as usize >= VOCAB) {
        Some(t) => bail!("token id {t} outside vocab [0, {VOCAB})"),
        None => Ok(()),
    }
}

/// Counters accumulated across the whole pool (plus queue gauges
/// sampled by [`Engine::stats`]).
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub dispatches: u64,
    pub rows: u64,
    pub exec_secs: f64,
    pub compile_secs: f64,
    /// pooled-query memo hits/misses summed over all workers
    pub pooled_q_hits: u64,
    pub pooled_q_misses: u64,
    /// pool size and queue gauges (sampled at stats time)
    pub workers: u64,
    pub queue_depth: u64,
    pub max_queue_depth: u64,
}

enum Request {
    Score(ScoreRequest, mpsc::Sender<Result<ScoreResponse>>),
    Embed(EmbedRequest, mpsc::Sender<Result<Vec<f32>>>),
}

struct Queue {
    items: VecDeque<Request>,
    shutdown: bool,
    max_depth: usize,
}

struct Shared {
    queue: Mutex<Queue>,
    cv: Condvar,
}

/// Cloneable handle to the engine worker pool.
#[derive(Clone)]
pub struct Engine {
    shared: Arc<Shared>,
    exec: Arc<exec::ExecShared>,
    workers: usize,
    // joined by the last handle's drop
    joins: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl Engine {
    /// Start a single-worker engine. Modules are compiled lazily on
    /// first use unless listed in `precompile`.
    pub fn start(manifest: Manifest, precompile: &[usize]) -> Result<Engine> {
        Self::start_pool(manifest, precompile, 1)
    }

    /// Start a pool of `workers` engine threads sharing one work queue
    /// and one `Arc`-loaded weight store. Precompilation happens on the
    /// caller thread so startup errors surface before any worker spawns.
    pub fn start_pool(manifest: Manifest, precompile: &[usize], workers: usize) -> Result<Engine> {
        let workers = workers.max(1);
        let exec = Arc::new(exec::ExecShared::new(manifest)?);
        for d in precompile {
            exec.ensure_score(*d)?;
        }
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue {
                items: VecDeque::new(),
                shutdown: false,
                max_depth: 0,
            }),
            cv: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let sh = Arc::clone(&shared);
            let ex = Arc::clone(&exec);
            let h = std::thread::Builder::new()
                .name(format!("engine-{i}"))
                .spawn(move || worker_main(sh, ex))
                .context("spawning engine worker")?;
            handles.push(h);
        }
        Ok(Engine {
            shared,
            exec,
            workers,
            joins: Arc::new(Mutex::new(handles)),
        })
    }

    /// Convenience: start from the default artifact dir.
    pub fn start_default() -> Result<Engine> {
        let manifest = Manifest::load(super::manifest::default_artifact_dir())?;
        Engine::start(manifest, &[])
    }

    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    fn enqueue(&self, req: Request) -> Result<()> {
        {
            let mut q = unpoisoned(&self.shared.queue);
            if q.shutdown {
                bail!("engine is shut down");
            }
            q.items.push_back(req);
            let depth = q.items.len();
            if depth > q.max_depth {
                q.max_depth = depth;
            }
        }
        self.shared.cv.notify_one();
        Ok(())
    }

    pub fn score(&self, req: ScoreRequest) -> Result<ScoreResponse> {
        req.validate()?;
        let (tx, rx) = mpsc::channel();
        self.enqueue(Request::Score(req, tx))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    pub fn embed(&self, req: EmbedRequest) -> Result<Vec<f32>> {
        req.validate()?;
        let (tx, rx) = mpsc::channel();
        self.enqueue(Request::Embed(req, tx))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    /// Pool-wide counters plus sampled queue gauges. No worker
    /// round-trip: counters live in the shared exec state.
    pub fn stats(&self) -> EngineStats {
        let mut s = self.exec.stats();
        s.workers = self.workers as u64;
        let q = unpoisoned(&self.shared.queue);
        s.queue_depth = q.items.len() as u64;
        s.max_queue_depth = q.max_depth as u64;
        s
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        if Arc::strong_count(&self.joins) != 1 {
            return;
        }
        {
            let mut q = unpoisoned(&self.shared.queue);
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        let mut handles = unpoisoned(&self.joins);
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Worker loop: pop-or-wait, execute, reply. On shutdown the queue is
/// drained before exiting so accepted requests still get answers.
fn worker_main(shared: Arc<Shared>, exec: Arc<exec::ExecShared>) {
    let mut memo = PooledQueryCache::new(DEFAULT_POOLED_QUERY_CAP);
    loop {
        let req = {
            let mut q = unpoisoned(&shared.queue);
            loop {
                if let Some(item) = q.items.pop_front() {
                    break Some(item);
                }
                if q.shutdown {
                    break None;
                }
                q = cv_wait(&shared.cv, q);
            }
        };
        let Some(req) = req else { return };
        match req {
            Request::Score(r, reply) => {
                let res = exec.run_score(&r, &mut memo);
                let _ = reply.send(res);
            }
            Request::Embed(r, reply) => {
                let res = exec.run_embed(&r);
                let _ = reply.send(res);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Offline execution path: the native-oracle math over artifact weights
// ---------------------------------------------------------------------------

#[cfg(not(feature = "xla-pjrt"))]
mod exec {
    use super::super::native::{
        embed_kernel, load_model_weights, score_kernel_memo, ModelWeights, PooledQueryCache,
    };
    use super::{EmbedRequest, EngineStats, Manifest, Result, ScoreRequest, ScoreResponse};
    use crate::util::sync::unpoisoned;
    use std::collections::BTreeMap;
    use std::sync::{Arc, Mutex};
    use std::time::Instant;

    /// Weight store and counters shared by every worker in the pool.
    /// Weights load once under the map lock and hand out as `Arc`s, so
    /// N workers share a single copy of each embedding table.
    pub(super) struct ExecShared {
        manifest: Manifest,
        score_weights: Mutex<BTreeMap<usize, Arc<ModelWeights>>>,
        embed_weights: Mutex<Option<Arc<ModelWeights>>>,
        stats: Mutex<EngineStats>,
    }

    impl ExecShared {
        pub(super) fn new(manifest: Manifest) -> Result<ExecShared> {
            Ok(ExecShared {
                manifest,
                score_weights: Mutex::new(BTreeMap::new()),
                embed_weights: Mutex::new(None),
                stats: Mutex::new(EngineStats::default()),
            })
        }

        pub(super) fn ensure_score(&self, d: usize) -> Result<Arc<ModelWeights>> {
            let mut map = unpoisoned(&self.score_weights);
            if let Some(w) = map.get(&d) {
                return Ok(Arc::clone(w));
            }
            // Load under the lock so a cold pool loads each table once.
            let t0 = Instant::now();
            let path = self.manifest.score_module(d)?.weights.clone();
            let w = Arc::new(load_model_weights(&path, d)?);
            unpoisoned(&self.stats).compile_secs += t0.elapsed().as_secs_f64();
            map.insert(d, Arc::clone(&w));
            Ok(w)
        }

        fn ensure_embed(&self) -> Result<Arc<ModelWeights>> {
            let mut slot = unpoisoned(&self.embed_weights);
            if let Some(w) = slot.as_ref() {
                return Ok(Arc::clone(w));
            }
            let t0 = Instant::now();
            let spec = self.manifest.embed_module()?;
            let (path, d) = (spec.weights.clone(), spec.d);
            let w = Arc::new(load_model_weights(&path, d)?);
            unpoisoned(&self.stats).compile_secs += t0.elapsed().as_secs_f64();
            *slot = Some(Arc::clone(&w));
            Ok(w)
        }

        pub(super) fn run_score(
            &self,
            req: &ScoreRequest,
            memo: &mut PooledQueryCache,
        ) -> Result<ScoreResponse> {
            let w = self.ensure_score(req.d)?;
            let t0 = Instant::now();
            let resp = score_kernel_memo(&w.emb, &w.wpos, w.d, req, memo);
            let secs = t0.elapsed().as_secs_f64();
            let (hits, misses) = memo.take_counters();
            let mut stats = unpoisoned(&self.stats);
            stats.dispatches += 1;
            stats.rows += crate::vocab::BATCH as u64;
            stats.exec_secs += secs;
            stats.pooled_q_hits += hits;
            stats.pooled_q_misses += misses;
            Ok(resp)
        }

        pub(super) fn run_embed(&self, req: &EmbedRequest) -> Result<Vec<f32>> {
            let w = self.ensure_embed()?;
            let t0 = Instant::now();
            let out = embed_kernel(&w.emb, w.d, req);
            let secs = t0.elapsed().as_secs_f64();
            let mut stats = unpoisoned(&self.stats);
            stats.dispatches += 1;
            stats.rows += crate::vocab::BATCH as u64;
            stats.exec_secs += secs;
            Ok(out)
        }

        pub(super) fn stats(&self) -> EngineStats {
            unpoisoned(&self.stats).clone()
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT execution path (requires the external `xla` bindings crate)
// ---------------------------------------------------------------------------

#[cfg(feature = "xla-pjrt")]
mod exec {
    use super::super::manifest::ModuleSpec;
    use super::super::native::PooledQueryCache;
    use super::super::weights::WeightFile;
    use super::{
        EmbedRequest, EngineStats, Manifest, Result, ScoreRequest, ScoreResponse, BATCH, CHUNK,
        QLEN,
    };
    use crate::util::sync::unpoisoned;
    use anyhow::{anyhow, bail};
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex};
    use std::time::Instant;

    /// One PJRT CPU client owns all device state, so the whole path is
    /// serialized behind a single mutex: a worker pool adds queueing
    /// fairness but no parallelism on this backend. Pooled-query
    /// memoization is a no-op here — pooling happens inside the HLO.
    pub(super) struct ExecShared {
        state: Mutex<State>,
    }

    impl ExecShared {
        pub(super) fn new(manifest: Manifest) -> Result<ExecShared> {
            Ok(ExecShared {
                state: Mutex::new(State::new(manifest)?),
            })
        }

        pub(super) fn ensure_score(&self, d: usize) -> Result<()> {
            unpoisoned(&self.state).ensure_score(d)
        }

        pub(super) fn run_score(
            &self,
            req: &ScoreRequest,
            _memo: &mut PooledQueryCache,
        ) -> Result<ScoreResponse> {
            unpoisoned(&self.state).run_score(req)
        }

        pub(super) fn run_embed(&self, req: &EmbedRequest) -> Result<Vec<f32>> {
            unpoisoned(&self.state).run_embed(req)
        }

        pub(super) fn stats(&self) -> EngineStats {
            unpoisoned(&self.state).stats()
        }
    }

    struct LoadedModule {
        exe: xla::PjRtLoadedExecutable,
        /// device-resident weight buffers, in input order (emb [, wpos])
        weight_bufs: Vec<xla::PjRtBuffer>,
        spec: ModuleSpec,
    }

    struct State {
        client: xla::PjRtClient,
        manifest: Manifest,
        score_modules: HashMap<usize, LoadedModule>,
        embed_module: Option<LoadedModule>,
        weight_cache: HashMap<String, Arc<WeightFile>>,
        stats: EngineStats,
    }

    impl State {
        fn new(manifest: Manifest) -> Result<State> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu failed: {e:?}"))?;
            Ok(State {
                client,
                manifest,
                score_modules: HashMap::new(),
                embed_module: None,
                weight_cache: HashMap::new(),
                stats: EngineStats::default(),
            })
        }

        fn load_module(&mut self, spec: &ModuleSpec) -> Result<LoadedModule> {
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&spec.file)
                .map_err(|e| anyhow!("loading {}: {e:?}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", spec.name))?;

            // Stage weight tensors on-device once.
            let wkey = spec.weights.to_string_lossy().to_string();
            let wf = match self.weight_cache.get(&wkey) {
                Some(wf) => Arc::clone(wf),
                None => {
                    let wf = Arc::new(WeightFile::load(&spec.weights)?);
                    self.weight_cache.insert(wkey, Arc::clone(&wf));
                    wf
                }
            };
            let mut weight_bufs = Vec::new();
            for decl in &spec.inputs {
                if decl.name == "emb" || decl.name == "wpos" {
                    let t = wf.get(&decl.name)?;
                    if t.dims != decl.shape {
                        bail!(
                            "weight '{}' shape {:?} != declared {:?}",
                            decl.name,
                            t.dims,
                            decl.shape
                        );
                    }
                    let buf = buffer_f32(&self.client, &t.data, &t.dims)
                        .map_err(|e| anyhow!("staging weight '{}': {e}", decl.name))?;
                    weight_bufs.push(buf);
                }
            }
            self.stats.compile_secs += t0.elapsed().as_secs_f64();
            Ok(LoadedModule {
                exe,
                weight_bufs,
                spec: spec.clone(),
            })
        }

        fn ensure_score(&mut self, d: usize) -> Result<()> {
            if !self.score_modules.contains_key(&d) {
                let spec = self.manifest.score_module(d)?.clone();
                let m = self.load_module(&spec)?;
                self.score_modules.insert(d, m);
            }
            Ok(())
        }

        fn ensure_embed(&mut self) -> Result<()> {
            if self.embed_module.is_none() {
                let spec = self.manifest.embed_module()?.clone();
                self.embed_module = Some(self.load_module(&spec)?);
            }
            Ok(())
        }

        fn run_score(&mut self, req: &ScoreRequest) -> Result<ScoreResponse> {
            self.ensure_score(req.d)?;
            let b = BATCH;
            let Some(module) = self.score_modules.get(&req.d) else {
                bail!("score module d={} missing after ensure", req.d);
            };
            let q_tok = buffer_i32(&self.client, &req.q_tokens, &[b, QLEN])?;
            let q_w = buffer_f32(&self.client, &req.q_weights, &[b, QLEN])?;
            let c_tok = buffer_i32(&self.client, &req.c_tokens, &[b, CHUNK])?;
            let c_m = buffer_f32(&self.client, &req.c_mask, &[b, CHUNK])?;

            let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(6);
            for w in &module.weight_bufs {
                inputs.push(w);
            }
            inputs.push(&q_tok);
            inputs.push(&q_w);
            inputs.push(&c_tok);
            inputs.push(&c_m);

            let t0 = Instant::now();
            let result = module
                .exe
                .execute_b(&inputs)
                .map_err(|e| anyhow!("execute {}: {e:?}", module.spec.name))?;
            let out = first_output(&result)?
                .to_literal_sync()
                .map_err(|e| anyhow!("readback: {e:?}"))?;
            let (scores_lit, lse_lit) = out
                .to_tuple2()
                .map_err(|e| anyhow!("expected 2-tuple output: {e:?}"))?;
            let scores = scores_lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("scores readback: {e:?}"))?;
            let lse = lse_lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("lse readback: {e:?}"))?;
            self.stats.dispatches += 1;
            self.stats.rows += b as u64;
            self.stats.exec_secs += t0.elapsed().as_secs_f64();

            if scores.len() != b * CHUNK || lse.len() != b {
                bail!(
                    "unexpected output sizes: scores={} lse={}",
                    scores.len(),
                    lse.len()
                );
            }
            Ok(ScoreResponse { scores, lse })
        }

        fn run_embed(&mut self, req: &EmbedRequest) -> Result<Vec<f32>> {
            self.ensure_embed()?;
            let b = BATCH;
            let Some(module) = self.embed_module.as_ref() else {
                bail!("embed module missing after ensure");
            };
            let c_tok = buffer_i32(&self.client, &req.c_tokens, &[b, CHUNK])?;
            let c_m = buffer_f32(&self.client, &req.c_mask, &[b, CHUNK])?;
            let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
            for w in &module.weight_bufs {
                inputs.push(w);
            }
            inputs.push(&c_tok);
            inputs.push(&c_m);
            let t0 = Instant::now();
            let result = module
                .exe
                .execute_b(&inputs)
                .map_err(|e| anyhow!("execute embed: {e:?}"))?;
            let out = first_output(&result)?
                .to_literal_sync()
                .map_err(|e| anyhow!("readback: {e:?}"))?;
            let emb_lit = out
                .to_tuple1()
                .map_err(|e| anyhow!("expected 1-tuple output: {e:?}"))?;
            let emb = emb_lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("embed readback: {e:?}"))?;
            self.stats.dispatches += 1;
            self.stats.rows += b as u64;
            self.stats.exec_secs += t0.elapsed().as_secs_f64();
            Ok(emb)
        }

        fn stats(&self) -> EngineStats {
            self.stats.clone()
        }
    }

    /// The single output buffer of a one-device execution.
    fn first_output(result: &[Vec<xla::PjRtBuffer>]) -> Result<&xla::PjRtBuffer> {
        result
            .first()
            .and_then(|per_device| per_device.first())
            .ok_or_else(|| anyhow!("execute returned no output buffers"))
    }

    fn buffer_f32(
        client: &xla::PjRtClient,
        data: &[f32],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("staging f32 buffer: {e:?}"))
    }

    fn buffer_i32(
        client: &xla::PjRtClient,
        data: &[i32],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("staging i32 buffer: {e:?}"))
    }
}
