//! Pure-Rust reference scorer.
//!
//! Implements exactly the math of the lowered HLO modules (gather ->
//! weighted window pooling -> masked dot scores -> logsumexp) directly on
//! the weight tensors. Three roles:
//!
//! 1. **Cross-language oracle**: integration tests assert PJRT outputs
//!    match this implementation on the same weights (the HLO path and the
//!    native path must agree to float tolerance).
//! 2. **Fast test backend**: protocol/unit tests run against this backend
//!    so they don't need artifact compilation.
//! 3. **Offline engine kernel**: without the `xla-pjrt` feature the
//!    engine workers execute `score_kernel` / `embed_kernel` directly
//!    (see `runtime::engine`), so the serving stack runs everywhere.
//!
//! The scoring kernel is *factored* (DESIGN.md §11): instead of
//! recomputing the dot `q·(m_{c+j}·emb[tok_{c+j}])` for every `(c, j)`
//! pair — O(CHUNK·window·d) — it computes the per-position projection
//! `p[c] = q·(m_c·emb[tok_c])` once and then the 1-D convolution
//! `s[c] = Σ_j wpos[j]·p[c+j]` — O(CHUNK·d + CHUNK·window). The
//! per-element FP operations happen in the same order as the naive
//! loop, so results are bit-identical (the naive form is preserved as
//! [`crate::perf::score_kernel_reference`] and the parity tests below
//! compare bit patterns).

use super::engine::{EmbedRequest, ScoreRequest, ScoreResponse};
use super::manifest::Manifest;
use super::weights::WeightFile;
use crate::util::sync::unpoisoned;
use crate::vocab::{BATCH, CHUNK, QLEN};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

pub const NEG_INF: f32 = -1.0e30;

/// Loaded weight tensors for one capacity `d`, shared (via `Arc`) by the
/// native backend and the offline engine workers.
pub(crate) struct ModelWeights {
    pub(crate) d: usize,
    pub(crate) emb: Vec<f32>,  // [V, d]
    pub(crate) wpos: Vec<f32>, // [W]
}

/// Load and shape-check the `emb`/`wpos` tensors for capacity `d`.
pub(crate) fn load_model_weights(path: &std::path::Path, d: usize) -> Result<ModelWeights> {
    let wf = WeightFile::load(path)?;
    let emb = wf.get("emb")?;
    let wpos = wf.get("wpos")?;
    if emb.dims.len() != 2 || emb.dims.last() != Some(&d) {
        bail!("emb dims {:?} inconsistent with d={d}", emb.dims);
    }
    Ok(ModelWeights {
        d,
        emb: emb.data.clone(),
        wpos: wpos.data.clone(),
    })
}

/// The embedding row for `tok`, or the empty slice when out of range.
///
/// Token ids are range-checked once at the serving surface
/// ([`ScoreRequest::validate`] / [`EmbedRequest::validate`]), so the
/// empty fallback is unreachable on the serving path; returning `&[]`
/// (a zero contribution through the zipped dot loops) keeps the kernel
/// itself panic-free. Wrapping arithmetic so a hostile `tok` cannot
/// overflow-panic in debug builds either.
#[inline]
fn emb_row(emb: &[f32], d: usize, tok: i32) -> &[f32] {
    let start = (tok as usize).wrapping_mul(d);
    emb.get(start..start.wrapping_add(d)).unwrap_or(&[])
}

/// Pool the weighted query embedding `q = Σ_j w_j·emb[tok_j]` into `q`,
/// skipping zero-weight slots exactly like the lowered HLO.
fn pool_query(emb: &[f32], d: usize, q_tokens: &[i32], q_weights: &[f32], q: &mut [f32]) {
    q.iter_mut().for_each(|x| *x = 0.0);
    for (&tok, &wgt) in q_tokens.iter().zip(q_weights) {
        if wgt == 0.0 {
            continue;
        }
        let row = emb_row(emb, d, tok);
        for (qk, &ek) in q.iter_mut().zip(row) {
            *qk += wgt * ek;
        }
    }
}

/// Score one row against a pooled query: the factored form.
///
/// Pass 1 computes `p[c] = q·(m_c·emb[tok_c])`; pass 2 the convolution
/// `s[c] = Σ_j wpos[j]·p[c+j]`. Bit-identity with the naive loop: the
/// naive form materializes `ce_k = m·e_k` (one f32 rounding) and then
/// accumulates `dot += q_k·ce_k` in `k` order; here `q_k·(m·e_k)`
/// evaluates `m·e_k` first with the same rounding, so the sequence of
/// FP operations is identical. For masked positions the naive dot over
/// a zeroed row sums `q_k·0.0` terms to `+0.0` (for finite `q`), which
/// is exactly the `p[c] = 0.0` written here. The convolution truncates
/// at the chunk edge via the `skip(c)` zip just like the reference's
/// `c + j >= CHUNK` break, in the same `j` order.
fn score_row(
    wpos: &[f32],
    q: &[f32],
    emb: &[f32],
    c_tokens: &[i32],
    c_mask: &[f32],
    p: &mut [f32],
    scores: &mut [f32],
) -> f32 {
    let d = q.len();
    // pass 1: masked per-position projections
    for ((pc, &m), &tok) in p.iter_mut().zip(c_mask).zip(c_tokens) {
        if m == 0.0 {
            *pc = 0.0;
            continue;
        }
        let row = emb_row(emb, d, tok);
        let mut dot = 0f32;
        for (&qk, &ek) in q.iter().zip(row) {
            dot += qk * (m * ek);
        }
        *pc = dot;
    }
    // pass 2: windowed convolution; masked positions stay NEG_INF
    let mut max_s = NEG_INF;
    for (c, (sc, &m)) in scores.iter_mut().zip(c_mask).enumerate() {
        if m == 0.0 {
            continue;
        }
        let mut s = 0f32;
        for (&wj, &pcj) in wpos.iter().zip(p.iter().skip(c)) {
            s += wj * pcj;
        }
        *sc = s;
        if s > max_s {
            max_s = s;
        }
    }
    // logsumexp over the row (f64 accumulator, as lowered)
    let mut sum = 0f64;
    for &s in scores.iter() {
        if s > NEG_INF / 2.0 {
            sum += ((s - max_s) as f64).exp();
        }
    }
    if sum > 0.0 {
        max_s + (sum as f32).ln()
    } else {
        NEG_INF
    }
}

/// Score one full batch: mirrors `python/compile/model.py::local_score_fn`.
/// `emb` is the `[V, d]` embedding table, `wpos` the window weights.
/// Shapes and token ranges are checked at the serving surfaces via
/// [`ScoreRequest::validate`].
pub(crate) fn score_kernel(
    emb: &[f32],
    wpos: &[f32],
    d: usize,
    req: &ScoreRequest,
) -> ScoreResponse {
    let mut scores = vec![NEG_INF; BATCH * CHUNK];
    let mut lse = vec![0f32; BATCH];
    let mut q = vec![0f32; d];
    let mut p = vec![0f32; CHUNK];
    let rows = req
        .q_tokens
        .chunks_exact(QLEN)
        .zip(req.q_weights.chunks_exact(QLEN))
        .zip(req.c_tokens.chunks_exact(CHUNK))
        .zip(req.c_mask.chunks_exact(CHUNK))
        .zip(scores.chunks_exact_mut(CHUNK))
        .zip(lse.iter_mut());
    for (((((qt, qw), ct), cm), srow), l) in rows {
        pool_query(emb, d, qt, qw, &mut q);
        *l = score_row(wpos, &q, emb, ct, cm, &mut p, srow);
    }
    ScoreResponse { scores, lse }
}

/// [`score_kernel`] with the pooled-query pass memoized through `memo`.
/// Bit-identical to the unmemoized kernel: a cache hit returns the very
/// vector a cold pooling pass would have produced (full key equality is
/// checked on hash match, so collisions can only miss, never alias).
pub(crate) fn score_kernel_memo(
    emb: &[f32],
    wpos: &[f32],
    d: usize,
    req: &ScoreRequest,
    memo: &mut PooledQueryCache,
) -> ScoreResponse {
    let mut scores = vec![NEG_INF; BATCH * CHUNK];
    let mut lse = vec![0f32; BATCH];
    let mut p = vec![0f32; CHUNK];
    let rows = req
        .q_tokens
        .chunks_exact(QLEN)
        .zip(req.q_weights.chunks_exact(QLEN))
        .zip(req.c_tokens.chunks_exact(CHUNK))
        .zip(req.c_mask.chunks_exact(CHUNK))
        .zip(scores.chunks_exact_mut(CHUNK))
        .zip(lse.iter_mut());
    for (((((qt, qw), ct), cm), srow), l) in rows {
        let q = memo.query(emb, d, qt, qw);
        *l = score_row(wpos, q, emb, ct, cm, &mut p, srow);
    }
    ScoreResponse { scores, lse }
}

/// Mean-pool chunk embedding: mirrors `embed_fn`.
pub(crate) fn embed_kernel(emb: &[f32], d: usize, req: &EmbedRequest) -> Vec<f32> {
    let mut out = vec![0f32; BATCH * d];
    let rows = req
        .c_tokens
        .chunks_exact(CHUNK)
        .zip(req.c_mask.chunks_exact(CHUNK))
        .zip(out.chunks_exact_mut(d));
    for ((ct, cm), orow) in rows {
        let mut count = 0f32;
        for (&tok, &m) in ct.iter().zip(cm) {
            if m == 0.0 {
                continue;
            }
            count += m;
            let row = emb_row(emb, d, tok);
            for (o, &e) in orow.iter_mut().zip(row) {
                *o += m * e;
            }
        }
        let denom = count.max(1.0);
        for o in orow.iter_mut() {
            *o /= denom;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Pooled-query memoization
// ---------------------------------------------------------------------------

/// Default per-worker capacity: a dispatch wave rarely carries more than
/// a few dozen distinct task instructions.
pub const DEFAULT_POOLED_QUERY_CAP: usize = 64;

/// Bounded per-worker LRU memoizing pooled query vectors by
/// `(d, hash(q_tokens, q_weights))`.
///
/// MinionS sends one task instruction across every chunk of a document,
/// so within a dispatch wave most rows share their query and the QLEN·d
/// pooling pass amortizes away. Reuse is bit-exact: on a hash match the
/// full token/weight key is compared before the cached vector is served,
/// so a collision can never substitute a different query's pooling — it
/// just misses and pools cold.
pub struct PooledQueryCache {
    cap: usize,
    entries: Vec<PooledEntry>,
    hits: u64,
    misses: u64,
}

struct PooledEntry {
    d: usize,
    hash: u64,
    tokens: Vec<i32>,
    weights: Vec<f32>,
    q: Vec<f32>,
}

impl PooledQueryCache {
    pub fn new(cap: usize) -> PooledQueryCache {
        PooledQueryCache {
            cap: cap.max(1),
            entries: Vec::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// The pooled query for `(q_tokens, q_weights)` at capacity `d`,
    /// pooling on miss. The returned slice is the most-recently-used
    /// entry (moved to the front, evicting past `cap`).
    pub fn query(&mut self, emb: &[f32], d: usize, q_tokens: &[i32], q_weights: &[f32]) -> &[f32] {
        let hash = pooled_query_key(d, q_tokens, q_weights);
        let found = self.entries.iter().position(|e| {
            e.hash == hash && e.d == d && e.tokens == q_tokens && e.weights == q_weights
        });
        match found {
            Some(i) => {
                self.hits += 1;
                let e = self.entries.remove(i);
                self.entries.insert(0, e);
            }
            None => {
                self.misses += 1;
                let mut q = vec![0f32; d];
                pool_query(emb, d, q_tokens, q_weights, &mut q);
                self.entries.insert(
                    0,
                    PooledEntry {
                        d,
                        hash,
                        tokens: q_tokens.to_vec(),
                        weights: q_weights.to_vec(),
                        q,
                    },
                );
                self.entries.truncate(self.cap);
            }
        }
        match self.entries.first() {
            Some(e) => &e.q,
            None => &[],
        }
    }

    /// Hit/miss counters since the last call (reset-on-read, so each
    /// worker can flush deltas into the shared `EngineStats`).
    pub fn take_counters(&mut self) -> (u64, u64) {
        (
            std::mem::take(&mut self.hits),
            std::mem::take(&mut self.misses),
        )
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// FNV-1a over the exact bit patterns of the key components.
fn pooled_query_key(d: usize, q_tokens: &[i32], q_weights: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut eat = |byte: u8| {
        h ^= byte as u64;
        h = h.wrapping_mul(0x100000001b3);
    };
    for byte in (d as u64).to_le_bytes() {
        eat(byte);
    }
    for t in q_tokens {
        for byte in t.to_le_bytes() {
            eat(byte);
        }
    }
    for w in q_weights {
        for byte in w.to_bits().to_le_bytes() {
            eat(byte);
        }
    }
    h
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

pub struct NativeBackend {
    manifest: Manifest,
    cache: Mutex<HashMap<usize, Arc<ModelWeights>>>,
    embed_d: usize,
}

impl NativeBackend {
    pub fn new(manifest: Manifest) -> Result<NativeBackend> {
        let embed_d = manifest.embed_module().map(|m| m.d).unwrap_or(128);
        Ok(NativeBackend {
            manifest,
            cache: Mutex::new(HashMap::new()),
            embed_d,
        })
    }

    pub fn from_default_artifacts() -> Result<NativeBackend> {
        let manifest = Manifest::load(super::manifest::default_artifact_dir())?;
        Self::new(manifest)
    }

    fn weights(&self, d: usize) -> Result<Arc<ModelWeights>> {
        let mut cache = unpoisoned(&self.cache);
        if let Some(w) = cache.get(&d) {
            return Ok(Arc::clone(w));
        }
        let spec = self
            .manifest
            .modules
            .iter()
            .find(|m| m.d == d)
            .with_context(|| format!("no module with d={d}"))?;
        let w = Arc::new(load_model_weights(&spec.weights, d)?);
        cache.insert(d, Arc::clone(&w));
        Ok(w)
    }

    /// Score one batch through the shared kernel.
    pub fn score(&self, req: &ScoreRequest) -> Result<ScoreResponse> {
        req.validate()?;
        let w = self.weights(req.d)?;
        Ok(score_kernel(&w.emb, &w.wpos, w.d, req))
    }

    /// Mean-pool chunk embedding through the shared kernel.
    pub fn embed(&self, req: &EmbedRequest) -> Result<Vec<f32>> {
        req.validate()?;
        let w = self.weights(self.embed_d)?;
        Ok(embed_kernel(&w.emb, w.d, req))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::score_kernel_reference;
    use crate::util::rng::Rng;
    use crate::vocab::WINDOW;

    /// Small synthetic vocab so the reference loop stays fast in debug.
    const TEST_VOCAB: usize = 256;

    fn rand_table(d: usize, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
        let emb = (0..TEST_VOCAB * d)
            .map(|_| (rng.f64() * 2.0 - 1.0) as f32)
            .collect();
        let wpos = (0..WINDOW).map(|_| rng.f64() as f32).collect();
        (emb, wpos)
    }

    fn rand_req(d: usize, rng: &mut Rng) -> ScoreRequest {
        let mask = |rng: &mut Rng| {
            let r = rng.f64();
            if r < 0.25 {
                0.0
            } else if r < 0.5 {
                0.5
            } else {
                1.0
            }
        };
        ScoreRequest {
            d,
            q_tokens: (0..BATCH * QLEN)
                .map(|_| rng.below(TEST_VOCAB) as i32)
                .collect(),
            q_weights: (0..BATCH * QLEN)
                .map(|_| {
                    if rng.f64() < 0.2 {
                        0.0
                    } else {
                        rng.f64() as f32
                    }
                })
                .collect(),
            c_tokens: (0..BATCH * CHUNK)
                .map(|_| rng.below(TEST_VOCAB) as i32)
                .collect(),
            c_mask: (0..BATCH * CHUNK).map(|_| mask(rng)).collect(),
        }
    }

    fn assert_bits_eq(fast: &ScoreResponse, slow: &ScoreResponse, tag: &str) {
        assert_eq!(fast.scores.len(), slow.scores.len(), "{tag}: scores len");
        for (i, (a, b)) in fast.scores.iter().zip(&slow.scores).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{tag}: scores[{i}]: {a} vs {b}");
        }
        assert_eq!(fast.lse.len(), slow.lse.len(), "{tag}: lse len");
        for (i, (a, b)) in fast.lse.iter().zip(&slow.lse).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{tag}: lse[{i}]: {a} vs {b}");
        }
    }

    #[test]
    fn factored_kernel_bit_identical_to_reference() {
        let mut rng = Rng::seed_from(41);
        for d in [64usize, 128, 256, 1024] {
            let (emb, wpos) = rand_table(d, &mut rng);
            for trial in 0..2 {
                let mut req = rand_req(d, &mut rng);
                if trial == 0 {
                    // row 0 fully masked; row 1 zero-weight query
                    for m in req.c_mask.iter_mut().take(CHUNK) {
                        *m = 0.0;
                    }
                    for w in req.q_weights.iter_mut().skip(QLEN).take(QLEN) {
                        *w = 0.0;
                    }
                }
                let fast = score_kernel(&emb, &wpos, d, &req);
                let slow = score_kernel_reference(&emb, &wpos, d, &req);
                assert_bits_eq(&fast, &slow, &format!("d={d} trial={trial}"));
            }
        }
    }

    #[test]
    fn memoized_kernel_bit_identical_and_counts_hits() {
        let mut rng = Rng::seed_from(43);
        let d = 64;
        let (emb, wpos) = rand_table(d, &mut rng);
        let mut req = rand_req(d, &mut rng);
        // all rows share one query: 1 miss + (BATCH-1) hits on a cold cache
        let qt: Vec<i32> = req.q_tokens.iter().take(QLEN).copied().collect();
        let qw: Vec<f32> = req.q_weights.iter().take(QLEN).copied().collect();
        for b in 1..BATCH {
            req.q_tokens[b * QLEN..(b + 1) * QLEN].copy_from_slice(&qt);
            req.q_weights[b * QLEN..(b + 1) * QLEN].copy_from_slice(&qw);
        }
        let mut memo = PooledQueryCache::new(DEFAULT_POOLED_QUERY_CAP);
        let fast = score_kernel_memo(&emb, &wpos, d, &req, &mut memo);
        let slow = score_kernel_reference(&emb, &wpos, d, &req);
        assert_bits_eq(&fast, &slow, "memo cold");
        assert_eq!(memo.take_counters(), (BATCH as u64 - 1, 1));
        // warm pass: all hits, still bit-identical
        let warm = score_kernel_memo(&emb, &wpos, d, &req, &mut memo);
        assert_bits_eq(&warm, &slow, "memo warm");
        assert_eq!(memo.take_counters(), (BATCH as u64, 0));
    }

    #[test]
    fn pooled_query_cache_is_bounded_and_collision_safe() {
        let mut rng = Rng::seed_from(47);
        let d = 64;
        let (emb, _) = rand_table(d, &mut rng);
        let mut memo = PooledQueryCache::new(2);
        let qs: Vec<(Vec<i32>, Vec<f32>)> = (0..3)
            .map(|i| {
                (
                    (0..QLEN).map(|j| (i * QLEN + j) as i32 % 200).collect(),
                    vec![0.5f32; QLEN],
                )
            })
            .collect();
        for (qt, qw) in &qs {
            memo.query(&emb, d, qt, qw);
        }
        assert_eq!(memo.len(), 2, "capacity bound");
        // the oldest entry was evicted: querying it again is a miss
        memo.take_counters();
        let (qt0, qw0) = (&qs[0].0, &qs[0].1);
        let got = memo.query(&emb, d, qt0, qw0).to_vec();
        assert_eq!(memo.take_counters(), (0, 1), "evicted entry misses");
        // and the served vector matches a cold pooling pass
        let mut want = vec![0f32; d];
        pool_query(&emb, d, qt0, qw0, &mut want);
        assert_eq!(got, want);
    }
}
