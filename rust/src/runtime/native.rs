//! Pure-Rust reference scorer.
//!
//! Implements exactly the math of the lowered HLO modules (gather ->
//! weighted window pooling -> masked dot scores -> logsumexp) directly on
//! the weight tensors. Three roles:
//!
//! 1. **Cross-language oracle**: integration tests assert PJRT outputs
//!    match this implementation on the same weights (the HLO path and the
//!    native path must agree to float tolerance).
//! 2. **Fast test backend**: protocol/unit tests run against this backend
//!    so they don't need artifact compilation.
//! 3. **Offline engine kernel**: without the `xla-pjrt` feature the
//!    engine thread executes `score_kernel` / `embed_kernel` directly
//!    (see `runtime::engine`), so the serving stack runs everywhere.

use super::engine::{EmbedRequest, ScoreRequest, ScoreResponse};
use super::manifest::Manifest;
use super::weights::WeightFile;
use crate::vocab::{BATCH, CHUNK, QLEN};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::Mutex;

pub const NEG_INF: f32 = -1.0e30;

struct ModelWeights {
    d: usize,
    emb: Vec<f32>,  // [V, d]
    wpos: Vec<f32>, // [W]
}

/// Score one full batch: mirrors `python/compile/model.py::local_score_fn`.
/// `emb` is the `[V, d]` embedding table, `wpos` the window weights.
/// Shapes are the caller's responsibility (`[BATCH*QLEN]` / `[BATCH*CHUNK]`).
pub(crate) fn score_kernel(
    emb: &[f32],
    wpos: &[f32],
    d: usize,
    req: &ScoreRequest,
) -> ScoreResponse {
    let b = BATCH;
    let window = wpos.len();
    let mut scores = vec![NEG_INF; b * CHUNK];
    let mut lse = vec![0f32; b];
    let mut q = vec![0f32; d];
    // reusable masked-embedding buffer for one row
    let mut ce = vec![0f32; CHUNK * d];
    for bi in 0..b {
        // pooled query
        q.iter_mut().for_each(|x| *x = 0.0);
        for j in 0..QLEN {
            let wgt = req.q_weights[bi * QLEN + j];
            if wgt == 0.0 {
                continue;
            }
            let tok = req.q_tokens[bi * QLEN + j] as usize;
            let row = &emb[tok * d..(tok + 1) * d];
            for (qk, ek) in q.iter_mut().zip(row) {
                *qk += wgt * ek;
            }
        }
        // masked token embeddings
        for c in 0..CHUNK {
            let m = req.c_mask[bi * CHUNK + c];
            let dst = &mut ce[c * d..(c + 1) * d];
            if m == 0.0 {
                dst.iter_mut().for_each(|x| *x = 0.0);
            } else {
                let tok = req.c_tokens[bi * CHUNK + c] as usize;
                let row = &emb[tok * d..(tok + 1) * d];
                for (o, e) in dst.iter_mut().zip(row) {
                    *o = m * e;
                }
            }
        }
        // windowed score: s[c] = q . sum_j wpos[j]*ce[c+j]
        let mut max_s = NEG_INF;
        for c in 0..CHUNK {
            let m = req.c_mask[bi * CHUNK + c];
            if m == 0.0 {
                continue; // stays NEG_INF
            }
            let mut s = 0f32;
            for (j, &wj) in wpos.iter().enumerate().take(window) {
                if c + j >= CHUNK {
                    break;
                }
                let row = &ce[(c + j) * d..(c + j + 1) * d];
                let mut dot = 0f32;
                for (qk, ek) in q.iter().zip(row) {
                    dot += qk * ek;
                }
                s += wj * dot;
            }
            scores[bi * CHUNK + c] = s;
            if s > max_s {
                max_s = s;
            }
        }
        // logsumexp over the row
        let mut sum = 0f64;
        for c in 0..CHUNK {
            let s = scores[bi * CHUNK + c];
            if s > NEG_INF / 2.0 {
                sum += ((s - max_s) as f64).exp();
            }
        }
        lse[bi] = if sum > 0.0 {
            max_s + (sum as f32).ln()
        } else {
            NEG_INF
        };
    }
    ScoreResponse { scores, lse }
}

/// Mean-pool chunk embedding: mirrors `embed_fn`.
pub(crate) fn embed_kernel(emb: &[f32], d: usize, req: &EmbedRequest) -> Vec<f32> {
    let b = BATCH;
    let mut out = vec![0f32; b * d];
    for bi in 0..b {
        let mut count = 0f32;
        for c in 0..CHUNK {
            let m = req.c_mask[bi * CHUNK + c];
            if m == 0.0 {
                continue;
            }
            count += m;
            let tok = req.c_tokens[bi * CHUNK + c] as usize;
            let row = &emb[tok * d..(tok + 1) * d];
            let dst = &mut out[bi * d..(bi + 1) * d];
            for (o, e) in dst.iter_mut().zip(row) {
                *o += m * e;
            }
        }
        let denom = count.max(1.0);
        for o in &mut out[bi * d..(bi + 1) * d] {
            *o /= denom;
        }
    }
    out
}

pub struct NativeBackend {
    manifest: Manifest,
    cache: Mutex<HashMap<usize, std::sync::Arc<ModelWeights>>>,
    embed_d: usize,
}

impl NativeBackend {
    pub fn new(manifest: Manifest) -> Result<NativeBackend> {
        let embed_d = manifest.embed_module().map(|m| m.d).unwrap_or(128);
        Ok(NativeBackend {
            manifest,
            cache: Mutex::new(HashMap::new()),
            embed_d,
        })
    }

    pub fn from_default_artifacts() -> Result<NativeBackend> {
        let manifest = Manifest::load(super::manifest::default_artifact_dir())?;
        Self::new(manifest)
    }

    fn weights(&self, d: usize) -> Result<std::sync::Arc<ModelWeights>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(w) = cache.get(&d) {
            return Ok(std::sync::Arc::clone(w));
        }
        let spec = self
            .manifest
            .modules
            .iter()
            .find(|m| m.d == d)
            .with_context(|| format!("no module with d={d}"))?;
        let wf = WeightFile::load(&spec.weights)?;
        let emb = wf.get("emb")?;
        let wpos = wf.get("wpos")?;
        if emb.dims.len() != 2 || emb.dims[1] != d {
            bail!("emb dims {:?} inconsistent with d={d}", emb.dims);
        }
        let w = std::sync::Arc::new(ModelWeights {
            d,
            emb: emb.data.clone(),
            wpos: wpos.data.clone(),
        });
        cache.insert(d, std::sync::Arc::clone(&w));
        Ok(w)
    }

    /// Score one batch through the shared kernel.
    pub fn score(&self, req: &ScoreRequest) -> Result<ScoreResponse> {
        let w = self.weights(req.d)?;
        if req.q_tokens.len() != BATCH * QLEN || req.c_tokens.len() != BATCH * CHUNK {
            bail!("native score shape mismatch");
        }
        Ok(score_kernel(&w.emb, &w.wpos, w.d, req))
    }

    /// Mean-pool chunk embedding through the shared kernel.
    pub fn embed(&self, req: &EmbedRequest) -> Result<Vec<f32>> {
        let w = self.weights(self.embed_d)?;
        if req.c_tokens.len() != BATCH * CHUNK {
            bail!("native embed shape mismatch");
        }
        Ok(embed_kernel(&w.emb, w.d, req))
    }
}
