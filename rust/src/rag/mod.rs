//! Retrieval-augmented generation baselines (paper §6.5 / Appendix E.3).
//!
//! Two retrievers over the context's chunks:
//! - [`bm25`]: lexical BM25, from scratch
//! - dense: the `embed` HLO artifact (the stand-in for OpenAI
//!   text-embedding-3-small) with cosine ranking
//!
//! The RAG protocol retrieves top-k chunks and ships them *raw* to the
//! remote model — the remote pays prefill for every retrieved token
//! (unlike MinionS, where the local model ships compact answers).

pub mod bm25;

use crate::cost::{text_tokens, Ledger};
use crate::data::{Answer, Context, QueryKind, Sample};
use crate::model::job::ChunkRef;
use crate::model::RemoteLm;
use crate::protocol::{OneShotSession, Outcome, Protocol, ProtocolSession};
use crate::runtime::{Backend, EmbedRequest};
use crate::util::rng::Rng;
use crate::vocab::{Token, BATCH, CHUNK, PAD};
use anyhow::Result;
use bm25::Bm25Index;
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Retriever {
    Bm25,
    Dense,
}

/// Enumerate retrieval units: `pages_per_chunk`-page chunks across docs.
pub fn retrieval_chunks(ctx: &Context, pages_per_chunk: usize) -> Vec<(ChunkRef, Vec<Token>)> {
    let mut out = Vec::new();
    for (di, doc) in ctx.docs.iter().enumerate() {
        let mut p = 0;
        while p < doc.n_pages() {
            let r = ChunkRef {
                doc: di,
                page_start: p,
                n_pages: pages_per_chunk.min(doc.n_pages() - p),
            };
            let mut toks = Vec::with_capacity(r.n_pages * crate::data::PAGE_TOKENS);
            for page in &doc.pages[p..p + r.n_pages] {
                toks.extend_from_slice(page);
            }
            out.push((r, toks));
            p += pages_per_chunk;
        }
    }
    out
}

pub struct Rag {
    pub remote: Arc<RemoteLm>,
    pub backend: Arc<dyn Backend>,
    pub retriever: Retriever,
    pub top_k: usize,
    pub pages_per_chunk: usize,
}

impl Clone for Rag {
    fn clone(&self) -> Self {
        Rag {
            remote: Arc::clone(&self.remote),
            backend: Arc::clone(&self.backend),
            retriever: self.retriever,
            top_k: self.top_k,
            pages_per_chunk: self.pages_per_chunk,
        }
    }
}

impl Rag {
    pub fn new(
        remote: Arc<RemoteLm>,
        backend: Arc<dyn Backend>,
        retriever: Retriever,
        top_k: usize,
    ) -> Self {
        Rag {
            remote,
            backend,
            retriever,
            top_k,
            pages_per_chunk: 2,
        }
    }

    /// Spec-path constructor (`kind = "rag-bm25"` or `"rag-dense"`):
    /// the kind picks the retriever, `top_k` sets retrieval depth.
    pub fn from_spec(
        spec: &crate::protocol::ProtocolSpec,
        remote: Arc<RemoteLm>,
        backend: Arc<dyn Backend>,
    ) -> Result<Rag> {
        let retriever = spec.retriever().ok_or_else(|| {
            anyhow::anyhow!("spec kind '{}' is not a RAG protocol", spec.kind.as_str())
        })?;
        Ok(Rag::new(remote, backend, retriever, spec.top_k))
    }

    /// Rank chunks for the query; returns chunk indices.
    fn retrieve(
        &self,
        query_tokens: &[Token],
        chunks: &[(ChunkRef, Vec<Token>)],
    ) -> Result<Vec<usize>> {
        match self.retriever {
            Retriever::Bm25 => {
                let texts: Vec<Vec<Token>> = chunks.iter().map(|(_, t)| t.clone()).collect();
                let idx = Bm25Index::build(&texts);
                Ok(idx
                    .search(query_tokens, self.top_k)
                    .into_iter()
                    .map(|(c, _)| c)
                    .collect())
            }
            Retriever::Dense => {
                // embed all chunks through the PJRT embed artifact, then
                // cosine-rank against the mean query-token embedding
                let mut embs: Vec<Vec<f32>> = Vec::with_capacity(chunks.len() + 1);
                // first row of the first batch carries the query "chunk"
                let mut rows: Vec<Vec<Token>> = Vec::with_capacity(chunks.len() + 1);
                rows.push(query_tokens.to_vec());
                rows.extend(chunks.iter().map(|(_, t)| t.clone()));
                for batch in rows.chunks(BATCH) {
                    let mut c_tokens = vec![0i32; BATCH * CHUNK];
                    let mut c_mask = vec![0f32; BATCH * CHUNK];
                    for (b, row) in batch.iter().enumerate() {
                        for (i, t) in row.iter().take(CHUNK).enumerate() {
                            if *t == PAD {
                                continue;
                            }
                            c_tokens[b * CHUNK + i] = *t as i32;
                            c_mask[b * CHUNK + i] = 1.0;
                        }
                    }
                    let emb = self.backend.embed(EmbedRequest { c_tokens, c_mask })?;
                    let d = emb.len() / BATCH;
                    for b in 0..batch.len() {
                        embs.push(emb[b * d..(b + 1) * d].to_vec());
                    }
                }
                let q = &embs[0];
                let mut scored: Vec<(usize, f64)> = embs[1..]
                    .iter()
                    .enumerate()
                    .map(|(i, e)| (i, cosine(q, e)))
                    .collect();
                scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then_with(|| a.0.cmp(&b.0)));
                Ok(scored.into_iter().take(self.top_k).map(|(c, _)| c).collect())
            }
        }
    }
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for (x, y) in a.iter().zip(b) {
        dot += (*x as f64) * (*y as f64);
        na += (*x as f64) * (*x as f64);
        nb += (*y as f64) * (*y as f64);
    }
    dot / (na.sqrt() * nb.sqrt() + 1e-12)
}

impl Protocol for Rag {
    fn name(&self) -> String {
        format!(
            "rag-{}[k={}]",
            match self.retriever {
                Retriever::Bm25 => "bm25",
                Retriever::Dense => "dense",
            },
            self.top_k
        )
    }

    fn session(&self, sample: &Sample) -> Box<dyn ProtocolSession> {
        let rag = self.clone();
        let sample = sample.clone();
        OneShotSession::boxed(move |rng| rag.answer(&sample, rng))
    }
}

impl Rag {
    /// Retrieve-then-read, in one blocking pass (the session's only step).
    fn answer(&self, sample: &Sample, rng: &mut Rng) -> Result<Outcome> {
        let mut ledger = Ledger::default();
        let q = &sample.query;
        let chunks = retrieval_chunks(&sample.context, self.pages_per_chunk);

        // query tokens: the key components (the lexical handle RAG gets)
        let mut query_tokens: Vec<Token> = Vec::new();
        for k in &q.keys {
            query_tokens.extend(k.0.iter().filter(|t| **t != PAD));
        }

        let picked = self.retrieve(&query_tokens, &chunks)?;

        // Build the retrieved sub-context and ship it to the remote.
        let retrieved_tokens: usize = picked.iter().map(|i| chunks[*i].1.len()).sum();
        ledger.remote_msg(retrieved_tokens as u64 + text_tokens(&q.text), 80);

        // The remote answers over the retrieved chunks only.
        let sub_ctx = subcontext(&sample.context, &chunks, &picked);
        let mut internal = Ledger::default(); // remote's reading is internal
        let answer = if picked.is_empty() {
            match &q.kind {
                QueryKind::Bool => Answer::Bool(false),
                QueryKind::Summarize => Answer::Set(vec![]),
                QueryKind::Compute(_) => Answer::Number(f64::NAN),
                QueryKind::Multi(_) => Answer::Set(vec![]),
                QueryKind::Extract => Answer::Value(0),
            }
        } else {
            self.remote
                .answer_full_context(&sub_ctx, q, rng, &mut internal)?
        };

        Ok(Outcome {
            answer,
            ledger,
            rounds: 1,
            transcript: vec![format!(
                "rag retrieved {}/{} chunks ({} tokens)",
                picked.len(),
                chunks.len(),
                retrieved_tokens
            )],
        })
    }
}

/// Materialize the retrieved chunks as a standalone context document.
fn subcontext(
    _ctx: &Context,
    chunks: &[(ChunkRef, Vec<Token>)],
    picked: &[usize],
) -> Context {
    use crate::data::{Document, PAGE_TOKENS};
    let mut pages = Vec::new();
    for i in picked {
        let toks = &chunks[*i].1;
        for page in toks.chunks(PAGE_TOKENS) {
            let mut p = page.to_vec();
            p.resize(PAGE_TOKENS, PAD);
            pages.push(p);
        }
    }
    if pages.is_empty() {
        pages.push(vec![PAD; PAGE_TOKENS]);
    }
    Context {
        docs: vec![Document { pages }],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ContextBuilder;

    #[test]
    fn retrieval_chunks_cover_context() {
        let mut rng = Rng::seed_from(3);
        let ctx = ContextBuilder::new(2, 6, &mut rng).finish();
        let chunks = retrieval_chunks(&ctx, 2);
        assert_eq!(chunks.len(), 6); // 3 per doc
        let total: usize = chunks.iter().map(|(_, t)| t.len()).sum();
        assert_eq!(total, ctx.total_tokens());
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-9);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-9);
    }

    #[test]
    fn subcontext_preserves_tokens() {
        let mut rng = Rng::seed_from(4);
        let ctx = ContextBuilder::new(1, 4, &mut rng).finish();
        let chunks = retrieval_chunks(&ctx, 2);
        let sub = subcontext(&ctx, &chunks, &[1]);
        assert_eq!(sub.docs.len(), 1);
        assert_eq!(sub.total_tokens(), chunks[1].1.len());
        assert_eq!(sub.docs[0].pages[0], ctx.docs[0].pages[2]);
    }
}
