//! BM25 over token chunks, from scratch (Robertson & Zaragoza 2009).
//!
//! The RAG baseline of §6.5: retrieve top-k chunks for the query and ship
//! them (raw) to the remote model. Documents are synthetic token
//! sequences, so "terms" are token ids — the same lexical space the
//! scorer model reads.

use crate::vocab::Token;
use std::collections::HashMap;

pub struct Bm25Index {
    /// term -> (chunk_id, term_frequency)
    postings: HashMap<Token, Vec<(usize, u32)>>,
    doc_len: Vec<usize>,
    avg_len: f64,
    n_docs: usize,
    pub k1: f64,
    pub b: f64,
}

impl Bm25Index {
    pub fn build(chunks: &[Vec<Token>]) -> Bm25Index {
        Self::build_tuned(chunks, 1.2, 0.75)
    }

    pub fn build_tuned(chunks: &[Vec<Token>], k1: f64, b: f64) -> Bm25Index {
        let mut postings: HashMap<Token, Vec<(usize, u32)>> = HashMap::new();
        let mut doc_len = Vec::with_capacity(chunks.len());
        for (ci, chunk) in chunks.iter().enumerate() {
            doc_len.push(chunk.len());
            let mut tf: HashMap<Token, u32> = HashMap::new();
            for t in chunk {
                *tf.entry(*t).or_insert(0) += 1;
            }
            for (t, f) in tf {
                postings.entry(t).or_default().push((ci, f));
            }
        }
        let n_docs = chunks.len();
        let avg_len = if n_docs == 0 {
            0.0
        } else {
            doc_len.iter().sum::<usize>() as f64 / n_docs as f64
        };
        Bm25Index {
            postings,
            doc_len,
            avg_len,
            n_docs,
            k1,
            b,
        }
    }

    fn idf(&self, term: Token) -> f64 {
        let df = self.postings.get(&term).map_or(0, |p| p.len()) as f64;
        let n = self.n_docs as f64;
        // BM25+-style floor at 0 to avoid negative idf for ubiquitous terms
        (((n - df + 0.5) / (df + 0.5)) + 1.0).ln().max(0.0)
    }

    /// Score every chunk against the query terms; returns (chunk, score)
    /// sorted descending, ties broken by chunk id (deterministic).
    pub fn search(&self, query: &[Token], top_k: usize) -> Vec<(usize, f64)> {
        let mut scores: HashMap<usize, f64> = HashMap::new();
        for term in query {
            let idf = self.idf(*term);
            if idf == 0.0 {
                continue;
            }
            if let Some(posts) = self.postings.get(term) {
                for (ci, tf) in posts {
                    let tf = *tf as f64;
                    let dl = self.doc_len[*ci] as f64;
                    let denom = tf + self.k1 * (1.0 - self.b + self.b * dl / self.avg_len);
                    *scores.entry(*ci).or_insert(0.0) += idf * tf * (self.k1 + 1.0) / denom;
                }
            }
        }
        let mut out: Vec<(usize, f64)> = scores.into_iter().collect();
        out.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap()
                .then_with(|| a.0.cmp(&b.0))
        });
        out.truncate(top_k);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunks() -> Vec<Vec<Token>> {
        vec![
            vec![100, 200, 300, 5000, 5001],     // exact query terms
            vec![100, 200, 999, 5002, 5003],     // partial
            vec![7000, 7001, 7002, 7003, 7004],  // unrelated
            vec![100, 100, 100, 100, 100],       // term spam (tf saturation)
        ]
    }

    #[test]
    fn exact_match_ranks_first() {
        let idx = Bm25Index::build(&chunks());
        let hits = idx.search(&[100, 200, 300], 4);
        assert_eq!(hits[0].0, 0);
        assert!(hits[0].1 > hits[1].1);
    }

    #[test]
    fn tf_saturates() {
        let idx = Bm25Index::build(&chunks());
        let hits = idx.search(&[100], 4);
        // chunk 3 has tf=5 of term 100, but saturation keeps chunk 0/1
        // within the same order of magnitude
        let spam = hits.iter().find(|(c, _)| *c == 3).unwrap().1;
        let normal = hits.iter().find(|(c, _)| *c == 0).unwrap().1;
        assert!(spam < normal * 3.0);
    }

    #[test]
    fn unrelated_chunk_unscored() {
        let idx = Bm25Index::build(&chunks());
        let hits = idx.search(&[100, 200, 300], 10);
        assert!(hits.iter().all(|(c, _)| *c != 2));
    }

    #[test]
    fn top_k_truncates_deterministically() {
        let idx = Bm25Index::build(&chunks());
        let a = idx.search(&[100], 2);
        let b = idx.search(&[100], 2);
        assert_eq!(a, b);
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn empty_index_no_panic() {
        let idx = Bm25Index::build(&[]);
        assert!(idx.search(&[1, 2, 3], 5).is_empty());
    }
}
