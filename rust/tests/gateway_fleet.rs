//! Fleet failover acceptance (DESIGN.md §13): kill a worker mid-session
//! and the gateway re-homes its WAL segments into a peer's state dir,
//! where adoption resumes the session — with the event stream served
//! through the gateway byte-identical to an uninterrupted run, and the
//! peer's re-persisted WAL byte-identical to the original's.
//!
//! The crash discipline mirrors `tests/durability.rs`: the "dead"
//! worker is a state dir holding a record-boundary prefix of a known
//! baseline WAL plus an address nothing listens on; the baseline and
//! the reference event stream come from one uninterrupted HTTP run on
//! an identical worker stack, so every compared byte is deterministic
//! (only the wall-clock `latency_ms` field is normalized).

mod testutil;

use minions::sched::DynamicBatcher;
use minions::server::gateway::{GatewayConfig, GatewayServer};
use minions::server::session::{SessionRunner, WalMode};
use minions::server::wal::segment::{self, SegmentConfig};
use minions::server::{http_get, http_get_raw, http_post, Metrics, Server, ServerState};
use minions::util::json::Json;
use std::collections::HashMap;
use std::net::TcpListener;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};
use testutil::{case_dir, datasets, factory, protocols, segment_lines_for, stack, write_wal};

const SEED: u64 = 11;
const TTL: Duration = Duration::from_secs(600);

/// A full serving worker on the deterministic pseudo-backend stack,
/// segmented-WAL-backed under `dir` — the same registry, seed, and
/// group-commit knobs on every instantiation, so two workers given the
/// same session produce the same bytes.
fn worker_state(dir: &Path) -> (Arc<ServerState>, Arc<DynamicBatcher>, Arc<SessionRunner>) {
    let s = stack();
    let protos = protocols(&s);
    let ds = datasets();
    let f = factory(&s);
    let cfg = SegmentConfig {
        commit_interval: Duration::ZERO,
        ..SegmentConfig::default()
    };
    let runner = SessionRunner::with_wal_mode(1, TTL, dir, WalMode::Segmented, cfg).unwrap();
    let batcher = Arc::clone(&s.batcher);
    let state = Arc::new(ServerState {
        datasets: ds,
        protocols: protos,
        aliases: HashMap::new(),
        factory: Some(f),
        metrics: Arc::new(Metrics::default()),
        seed: SEED,
        batcher: Some(Arc::clone(&batcher)),
        cache: None,
        engine: None,
        sessions: Arc::clone(&runner),
        max_sessions: 0,
    });
    (state, batcher, runner)
}

/// Split a raw chunked-transfer response into its payload lines.
fn dechunked_lines(raw: &str) -> Vec<String> {
    let body = raw.split_once("\r\n\r\n").map(|x| x.1).unwrap_or(raw);
    let mut lines = Vec::new();
    let mut rest = body;
    while let Some((size_hex, tail)) = rest.split_once("\r\n") {
        let Ok(size) = usize::from_str_radix(size_hex.trim(), 16) else {
            break;
        };
        if size == 0 || tail.len() < size {
            break;
        }
        lines.push(tail[..size].trim_end().to_string());
        rest = tail.get(size + 2..).unwrap_or("");
    }
    lines
}

/// Zero out the wall-clock `latency_ms` field so runs on different
/// workers compare equal; everything else is deterministic.
fn normalize_latency(line: &str) -> String {
    let mut out = String::new();
    let mut rest = line;
    while let Some(pos) = rest.find("\"latency_ms\":") {
        let after = pos + "\"latency_ms\":".len();
        out.push_str(&rest[..after]);
        out.push('0');
        let tail = &rest[after..];
        let end = tail
            .find(|c: char| !(c.is_ascii_digit() || ".eE+-".contains(c)))
            .unwrap_or(tail.len());
        rest = &tail[end..];
    }
    out.push_str(rest);
    out
}

fn event_lines(addr: &str, sid: u64) -> Vec<String> {
    let raw = http_get_raw(addr, &format!("/v1/sessions/{sid}/events")).unwrap();
    dechunked_lines(&raw)
        .iter()
        .map(|l| normalize_latency(l))
        .collect()
}

#[test]
fn killed_worker_session_migrates_to_peer_byte_identically() {
    // ---- uninterrupted reference: one HTTP run on worker R ----------
    let dir_r = case_dir("fleet-ref");
    let (state_r, batcher_r, runner_r) = worker_state(&dir_r);
    let server_r = Server::bind(state_r, "127.0.0.1:0", 2).unwrap();
    let addr_r = server_r.addr.to_string();
    std::thread::spawn(move || server_r.serve(None));

    let body = r#"{"dataset":"micro","sample":0,"protocol":"minions-2r"}"#;
    let resp = http_post(&addr_r, "/v1/sessions", body).unwrap();
    let sid = Json::parse(&resp)
        .unwrap()
        .get("session_id")
        .and_then(Json::as_u64)
        .unwrap();
    let ref_lines = event_lines(&addr_r, sid); // events-to-EOF barrier
    assert!(
        ref_lines.last().unwrap().contains("\"finalized\""),
        "{ref_lines:?}"
    );
    runner_r.shutdown(); // drain the group committer so segments are complete
    batcher_r.stop();
    let base_lines = segment_lines_for(&dir_r, sid);
    assert!(
        base_lines.len() >= 3,
        "need meta + step(s) + finalized, got {}",
        base_lines.len()
    );

    // ---- crash state: worker A is a WAL prefix + a dead address -----
    let root = case_dir("fleet-migration");
    let dir_a = root.join("worker-0");
    let dir_b = root.join("worker-1");
    std::fs::create_dir_all(&dir_a).unwrap();
    std::fs::create_dir_all(&dir_b).unwrap();
    // meta + first step: killed mid-session, well before the finalize
    write_wal(&segment::segment_path(&dir_a, 0), &base_lines[..2], None);
    let dead_addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
        // listener dropped: probes to this address are refused
    };

    // ---- the surviving peer and the gateway over both ---------------
    let (state_b, batcher_b, runner_b) = worker_state(&dir_b);
    let server_b = Server::bind(state_b, "127.0.0.1:0", 2).unwrap();
    let addr_b = server_b.addr.to_string();
    std::thread::spawn(move || server_b.serve(None));

    let mut cfg = GatewayConfig::new(vec![dead_addr, addr_b.clone()]);
    cfg.state_root = Some(root.clone());
    cfg.probe_interval = Duration::from_millis(50);
    cfg.probe_fails = 1;
    let gw = GatewayServer::bind(cfg, "127.0.0.1:0", 4).unwrap();
    let addr_g = gw.addr.to_string();
    std::thread::spawn(move || gw.serve(None));

    // failure detection → segment re-homing → adoption on the peer
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let m = Json::parse(&http_get(&addr_g, "/metrics").unwrap()).unwrap();
        if m.get("gateway_sessions_migrated").and_then(Json::as_u64) >= Some(1) {
            assert_eq!(m.get("gateway_workers_dead").unwrap().as_u64(), Some(1));
            assert_eq!(m.get("gateway_migrate_failures").unwrap().as_u64(), Some(0));
            break;
        }
        assert!(Instant::now() < deadline, "migration never completed: {m}");
        std::thread::sleep(Duration::from_millis(10));
    }

    // the resumed stream through the gateway is the uninterrupted one
    let migrated_lines = event_lines(&addr_g, sid);
    assert_eq!(
        migrated_lines, ref_lines,
        "migrated session's event stream must match the uninterrupted run"
    );
    let status = http_get(&addr_g, &format!("/v1/sessions/{sid}")).unwrap();
    assert!(status.contains("\"done\""), "{status}");

    // the dead worker's segments were archived, not deleted
    assert!(
        segment::scan_dir_sessions(&dir_a).unwrap().is_empty(),
        "re-homed segments must leave worker-0's scan empty"
    );
    assert!(dir_a.join("migrated").is_dir(), "archive dir missing");

    // and the peer's re-persisted WAL converged to the baseline bytes
    runner_b.shutdown();
    batcher_b.stop();
    assert_eq!(
        segment_lines_for(&dir_b, sid),
        base_lines,
        "adopted WAL must be byte-identical to the uninterrupted WAL"
    );
}

/// The auto satellite of the migration acceptance: a session created
/// from an inline `{"kind":"auto"}` spec is killed mid-run and adopted
/// by a peer, which resumes the *originally routed* rung from the v3
/// meta — the same decision bytes, never a re-probe (the peer's live
/// queue state differs, so a re-probe could route differently).
#[test]
fn auto_session_migrates_and_peer_resumes_the_routed_rung() {
    // ---- uninterrupted reference: one HTTP auto run on worker R -----
    let dir_r = case_dir("fleet-auto-ref");
    let (state_r, batcher_r, runner_r) = worker_state(&dir_r);
    let server_r = Server::bind(state_r, "127.0.0.1:0", 2).unwrap();
    let addr_r = server_r.addr.to_string();
    std::thread::spawn(move || server_r.serve(None));

    // quality-first over {local, minions} deterministically escalates
    // to MinionS whatever the probe reports — the decision is stable
    // even though live scheduler signals feed the generic cost function
    let body = http_post(
        &addr_r,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":0,"spec":{"kind":"auto","local":"llama-3b","route_weights":"0:0:1","allowed":["local","minions"]}}"#,
    )
    .unwrap();
    let resp = Json::parse(&body).unwrap();
    let sid = resp.get("session_id").and_then(Json::as_u64).unwrap();
    let routed = resp.get("routed").expect("create response carries the decision");
    assert_eq!(
        routed.get("chosen_kind").and_then(Json::as_str),
        Some("minions"),
        "{routed}"
    );
    assert_ne!(
        resp.get("protocol").and_then(Json::as_str),
        Some("auto"),
        "the create response names the resolved rung"
    );
    let routed_bytes = routed.to_string();
    let ref_lines = event_lines(&addr_r, sid); // events-to-EOF barrier
    assert!(
        ref_lines.last().unwrap().contains("\"finalized\""),
        "{ref_lines:?}"
    );
    runner_r.shutdown();
    batcher_r.stop();
    let base_lines = segment_lines_for(&dir_r, sid);
    assert!(
        base_lines.len() >= 3,
        "need meta + step(s) + finalized, got {}",
        base_lines.len()
    );
    // the meta record is v3: resolved spec + the decision, never "auto"
    let meta = Json::parse(&base_lines[0]).unwrap();
    let mbody = meta.get("body").unwrap();
    assert_eq!(mbody.get("version").and_then(Json::as_u64), Some(3));
    assert_eq!(mbody.get("routed").unwrap().to_string(), routed_bytes);

    // ---- crash state: worker A is a WAL prefix + a dead address -----
    let root = case_dir("fleet-auto-migration");
    let dir_a = root.join("worker-0");
    let dir_b = root.join("worker-1");
    std::fs::create_dir_all(&dir_a).unwrap();
    std::fs::create_dir_all(&dir_b).unwrap();
    write_wal(&segment::segment_path(&dir_a, 0), &base_lines[..2], None);
    let dead_addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };

    // ---- the surviving peer and the gateway over both ---------------
    let (state_b, batcher_b, runner_b) = worker_state(&dir_b);
    let server_b = Server::bind(state_b, "127.0.0.1:0", 2).unwrap();
    let addr_b = server_b.addr.to_string();
    std::thread::spawn(move || server_b.serve(None));

    let mut cfg = GatewayConfig::new(vec![dead_addr, addr_b.clone()]);
    cfg.state_root = Some(root.clone());
    cfg.probe_interval = Duration::from_millis(50);
    cfg.probe_fails = 1;
    let gw = GatewayServer::bind(cfg, "127.0.0.1:0", 4).unwrap();
    let addr_g = gw.addr.to_string();
    std::thread::spawn(move || gw.serve(None));

    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let m = Json::parse(&http_get(&addr_g, "/metrics").unwrap()).unwrap();
        if m.get("gateway_sessions_migrated").and_then(Json::as_u64) >= Some(1) {
            assert_eq!(m.get("gateway_migrate_failures").unwrap().as_u64(), Some(0));
            break;
        }
        assert!(Instant::now() < deadline, "migration never completed: {m}");
        std::thread::sleep(Duration::from_millis(10));
    }

    // the adopted session finishes on the originally routed rung and
    // its status body re-surfaces the persisted decision verbatim
    let migrated_lines = event_lines(&addr_g, sid);
    assert_eq!(
        migrated_lines, ref_lines,
        "migrated auto session's event stream must match the uninterrupted run"
    );
    let status = Json::parse(&http_get(&addr_g, &format!("/v1/sessions/{sid}")).unwrap()).unwrap();
    assert_eq!(status.get("status").and_then(Json::as_str), Some("done"));
    assert_eq!(
        status.get("routed").map(|r| r.to_string()),
        Some(routed_bytes),
        "adopted session must carry the original decision, not a re-probe"
    );
    assert_ne!(status.get("protocol").and_then(Json::as_str), Some("auto"));

    // the peer's re-persisted WAL converged to the baseline bytes —
    // including the v3 meta record with the routing decision
    runner_b.shutdown();
    batcher_b.stop();
    assert_eq!(
        segment_lines_for(&dir_b, sid),
        base_lines,
        "adopted WAL must be byte-identical to the uninterrupted WAL"
    );
}
