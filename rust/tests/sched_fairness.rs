//! QoS-scheduler fairness, admission-control, and shutdown-race tests
//! (artifact-free: stub backends and protocols stand in for compiled
//! weights, so these run in every environment — the tier-1 gate included).
//!
//! What they pin down:
//! - **No starvation**: under a saturating batch-lane sweep, an
//!   interactive session's rows are dispatched within a bounded number of
//!   flushes (deterministically via WFQ assembly, and under real threaded
//!   contention with a generous bound);
//! - **Occupancy floor**: two concurrent MinionS runs through the shared
//!   batcher keep occupancy above 0.5 (the PR-1 regression floor);
//! - **Saturated admission**: a full session registry yields HTTP 429
//!   with `Retry-After`, the shed request is counted in `/metrics`, no
//!   worker panics, and a later retry succeeds;
//! - **Backpressure determinism**: a run that hits `SchedError::Saturated`
//!   mid-flight backs off and retries **bit-identically** to an unloaded
//!   run;
//! - **Shutdown races**: concurrent submitters during
//!   `DynamicBatcher::stop` all get clean errors (no hang, no panic), and
//!   `SessionRunner::shutdown` with queued-but-unstarted sessions marks
//!   them failed instead of leaking waiters;
//! - **Registry bounding**: terminal sessions are evicted after the TTL
//!   (404 afterwards is documented behavior).

mod testutil;

use anyhow::Result;
use minions::cost::Ledger;
use minions::data::{self, Answer, Sample};
use minions::eval::{run_protocol, RunResult};
use minions::model::{local, remote, LocalLm, RemoteLm};
use minions::protocol::{
    MinionS, MinionsConfig, Outcome, Protocol, ProtocolSession, SessionEvent,
};
use minions::runtime::{Backend, EmbedRequest, Manifest, ScoreRequest, ScoreResponse};
use minions::sched::{
    is_saturated, lane_scope, parse_lane_weights, DynamicBatcher, Lane, ScoreRow, Ticket,
};
use minions::server::session::{SessionRunner, SessionStatus};
use minions::server::{http_get, http_post, http_post_raw, Metrics, Server, ServerState};
use minions::util::json::Json;
use minions::util::rng::Rng;
use minions::vocab::{BATCH, CHUNK, QLEN};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use testutil::{Gate, PseudoBackend};

// ---------------------------------------------------------------------
// Backends.
// ---------------------------------------------------------------------

/// Echo backend: score = row's first query token, lse = 1.
struct Echo;

impl Backend for Echo {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse> {
        let mut scores = vec![0f32; BATCH * CHUNK];
        for b in 0..BATCH {
            let v = req.q_tokens[b * QLEN] as f32;
            for s in &mut scores[b * CHUNK..(b + 1) * CHUNK] {
                *s = v;
            }
        }
        Ok(ScoreResponse {
            scores,
            lse: vec![1.0; BATCH],
        })
    }

    fn embed(&self, _req: EmbedRequest) -> Result<Vec<f32>> {
        unimplemented!()
    }

    fn name(&self) -> &'static str {
        "echo"
    }
}

/// Echo plus a fixed per-dispatch delay — creates real contention.
struct SlowEcho {
    delay: Duration,
}

impl Backend for SlowEcho {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse> {
        std::thread::sleep(self.delay);
        Echo.score(req)
    }

    fn embed(&self, _req: EmbedRequest) -> Result<Vec<f32>> {
        unimplemented!()
    }

    fn name(&self) -> &'static str {
        "slow-echo"
    }
}

// (the deterministic pseudo scorer lives in `testutil::PseudoBackend`,
// shared with the session-server and durability suites so the
// construction bit-identity rests on exists in exactly one place)

fn row(tag: i32) -> ScoreRow {
    ScoreRow {
        d: 128,
        q_tokens: {
            let mut v = vec![0i32; QLEN];
            v[0] = tag;
            v
        },
        q_weights: vec![0f32; QLEN],
        c_tokens: vec![0i32; CHUNK],
        c_mask: vec![1f32; CHUNK],
    }
}

fn stack(max_wait: Duration) -> (Arc<DynamicBatcher>, Arc<LocalLm>, Arc<RemoteLm>) {
    let batcher = DynamicBatcher::new(Arc::new(PseudoBackend), max_wait);
    let manifest = Manifest::stub_for_tests(&[64, 128, 256, 1024], vec![1.0, 0.5, 0.25]);
    let local =
        Arc::new(LocalLm::new(Arc::clone(&batcher), &manifest, local::LLAMA_3B).unwrap());
    let remote =
        Arc::new(RemoteLm::new(Arc::clone(&batcher), &manifest, remote::GPT_4O).unwrap());
    (batcher, local, remote)
}

fn assert_identical(a: &RunResult, b: &RunResult, label: &str) {
    assert_eq!(a.scores, b.scores, "{label}: scores diverged");
    assert_eq!(
        a.accuracy.to_bits(),
        b.accuracy.to_bits(),
        "{label}: accuracy diverged"
    );
    assert_eq!(a.cost.total, b.cost.total, "{label}: ledger diverged");
    for (i, (x, y)) in a.outcomes.iter().zip(&b.outcomes).enumerate() {
        assert_eq!(x.answer, y.answer, "{label}: answer {i} diverged");
        assert_eq!(x.ledger, y.ledger, "{label}: ledger {i} diverged");
        assert_eq!(x.rounds, y.rounds, "{label}: rounds {i} diverged");
    }
}

// ---------------------------------------------------------------------
// (a) No starvation: WFQ pulls interactive rows into the next flush.
// ---------------------------------------------------------------------

#[test]
fn interactive_row_rides_the_next_flush_despite_a_parked_batch_backlog() {
    // Deterministic variant: a far deadline means nothing flushes until a
    // slot fills, so the dispatch composition is exactly the WFQ/RR
    // assembly order — no timing involved.
    let b = DynamicBatcher::new(Arc::new(Echo), Duration::from_secs(30));
    for round in 0..3u64 {
        // 7 batch-lane rows parked across two sweep "sessions"
        let mut parked: Vec<Ticket> = Vec::new();
        for i in 0..(BATCH as i32 - 1) {
            let session = 1 + (i as u64 % 2);
            parked.push(b.submit_tagged(row(i), Lane::Batch, session).unwrap());
        }
        let before = b.snapshot().dispatches;
        // the interactive row completes the batch and must ride it: ONE
        // flush, not "after the sweep drains"
        let interactive = b.submit_tagged(row(777), Lane::Interactive, 9).unwrap();
        interactive.wait().unwrap();
        let after = b.snapshot().dispatches;
        assert_eq!(
            after - before,
            1,
            "round {round}: interactive row must be dispatched in the very next flush"
        );
        for t in parked {
            t.wait().unwrap();
        }
    }
    let snap = b.snapshot();
    assert_eq!(snap.lane_rows[Lane::Interactive.index()], 3);
    assert_eq!(snap.lane_rows[Lane::Batch.index()], 3 * (BATCH as u64 - 1));
    b.stop();
}

#[test]
fn interactive_rows_bounded_under_threaded_batch_saturation() {
    // Threaded variant: two batch-lane flooders keep the scheduler busy
    // against a slow backend; every interactive row must still complete
    // within a small, bounded number of global dispatches.
    let b = DynamicBatcher::new(
        Arc::new(SlowEcho {
            delay: Duration::from_millis(2),
        }),
        Duration::from_millis(5),
    );
    b.set_queue_depth(512);
    let stop = Arc::new(AtomicBool::new(false));
    let flood: Vec<_> = (0..2u64)
        .map(|f| {
            let b = Arc::clone(&b);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let _lane = lane_scope(Lane::Batch, f);
                let mut parked: VecDeque<Ticket> = VecDeque::new();
                while !stop.load(Ordering::Relaxed) {
                    while parked.len() < 32 {
                        match b.submit(row(1)) {
                            Ok(t) => parked.push_back(t),
                            Err(_) => break,
                        }
                    }
                    if let Some(t) = parked.pop_front() {
                        let _ = t.wait();
                    }
                }
                for t in parked {
                    let _ = t.wait();
                }
            })
        })
        .collect();
    // wait until the sweep is demonstrably saturating the dispatcher
    let deadline = Instant::now() + Duration::from_secs(10);
    while b.snapshot().dispatches < 5 {
        assert!(Instant::now() < deadline, "sweep never started dispatching");
        std::thread::sleep(Duration::from_millis(1));
    }
    let _lane = lane_scope(Lane::Interactive, 42);
    for i in 0..5 {
        let before = b.snapshot().dispatches;
        b.score_row(row(1000 + i)).unwrap();
        let waited = b.snapshot().dispatches - before;
        assert!(
            waited <= 16,
            "interactive row {i} starved: {waited} dispatches before completion"
        );
    }
    stop.store(true, Ordering::Relaxed);
    for h in flood {
        h.join().unwrap();
    }
    b.stop();
    let snap = b.snapshot();
    assert_eq!(snap.lane_rows[Lane::Interactive.index()], 5);
    assert!(snap.lane_rows[Lane::Batch.index()] > 5);
    // wait accounting flowed per lane
    assert!(snap.lane_wait_us[Lane::Batch.index()] > 0);
}

// ---------------------------------------------------------------------
// (b) Occupancy floor: the PR-1 regression gate still holds.
// ---------------------------------------------------------------------

#[test]
fn concurrent_minions_runs_keep_occupancy_above_half() {
    let (batcher, local, remote) = stack(Duration::from_millis(20));
    let proto: Arc<dyn Protocol> = Arc::new(MinionS::new(
        Arc::clone(&local),
        remote,
        MinionsConfig::default(),
    ));
    let ds = data::micro::context_sweep(8, 3, 7);
    std::thread::scope(|s| {
        let a = {
            let proto = Arc::clone(&proto);
            let ds = &ds;
            s.spawn(move || run_protocol(proto.as_ref(), ds, 21, true).unwrap())
        };
        let b = {
            let proto = Arc::clone(&proto);
            let ds = &ds;
            s.spawn(move || run_protocol(proto.as_ref(), ds, 22, true).unwrap())
        };
        a.join().unwrap();
        b.join().unwrap();
    });
    let snap = batcher.snapshot();
    assert!(snap.dispatches > 0);
    assert!(
        snap.occupancy > 0.5,
        "two concurrent MinionS runs should batch efficiently, got {:.3} ({snap:?})",
        snap.occupancy
    );
    batcher.stop();
}

// ---------------------------------------------------------------------
// Backpressure determinism: saturated runs retry bit-identically.
// ---------------------------------------------------------------------

#[test]
fn runs_interrupted_by_saturation_retry_bit_identically() {
    let ds = data::micro::multistep_sweep(2, 3, 3);

    // baseline: unloaded stack
    let (b0, local0, remote0) = stack(Duration::from_millis(2));
    let proto0 = MinionS::new(local0, remote0, MinionsConfig::default());
    let baseline = run_protocol(&proto0, &ds, 11, true).unwrap();
    b0.stop();

    // loaded: admission bound of one batch (batch-lane share 7), filled
    // by parked rows on capacities the protocol never uses (they flush on
    // the 10ms deadline, re-opening admission). The protocol's first
    // submissions hit Saturated, surface as SessionEvent::Backoff, and
    // retry — the final results must not care.
    let (b1, local1, remote1) = stack(Duration::from_millis(10));
    b1.set_queue_depth(BATCH);
    let batch_share = (BATCH - BATCH / 8) as i32;
    let mut parked = Vec::new();
    for i in 0..batch_share {
        let mut r = row(i);
        r.d = if i % 2 == 0 { 64 } else { 256 };
        parked.push(b1.submit_tagged(r, Lane::Batch, 0).unwrap());
    }
    let proto1 = MinionS::new(local1, remote1, MinionsConfig::default());
    let loaded = run_protocol(&proto1, &ds, 11, true).unwrap();
    for t in parked {
        t.wait().unwrap();
    }
    assert_identical(&baseline, &loaded, "saturated-then-retried run");
    b1.stop();
}

#[test]
fn saturated_submit_is_a_typed_retryable_error() {
    // a wide-ish deadline keeps the queue provably full while the first
    // assertion runs, even on a heavily loaded machine
    let b = DynamicBatcher::new(Arc::new(Echo), Duration::from_millis(100));
    b.set_queue_depth(BATCH);
    // fill the batch lane's admission share (7/8 of the bound)
    let mut parked = Vec::new();
    for i in 0..(BATCH - BATCH / 8) as i32 {
        let mut r = row(i);
        r.d = if i % 2 == 0 { 64 } else { 256 };
        parked.push(b.submit(r).unwrap());
    }
    let err = b.submit(row(50)).unwrap_err();
    assert!(is_saturated(&err), "expected Saturated, got: {err}");
    // the deadline flush drains the queue; admission then re-opens
    for t in parked {
        t.wait().unwrap();
    }
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        match b.submit(row(51)) {
            Ok(t) => {
                drop(t);
                break;
            }
            Err(e) => {
                assert!(is_saturated(&e), "unexpected error: {e}");
                assert!(Instant::now() < deadline, "admission never re-opened");
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
    assert!(b.snapshot().saturated >= 1);
    b.stop();
}

// ---------------------------------------------------------------------
// Shutdown-vs-submit races.
// ---------------------------------------------------------------------

#[test]
fn concurrent_submitters_during_stop_get_clean_errors() {
    let b = DynamicBatcher::new(Arc::new(Echo), Duration::from_millis(1));
    let stop_seen = Arc::new(AtomicBool::new(false));
    let handles: Vec<_> = (0..8i32)
        .map(|i| {
            let b = Arc::clone(&b);
            let stop_seen = Arc::clone(&stop_seen);
            std::thread::spawn(move || {
                let mut oks = 0usize;
                let mut errs = 0usize;
                for k in 0..300i32 {
                    match b.score_row(row(i * 1000 + k)) {
                        Ok(_) => oks += 1,
                        Err(e) => {
                            let msg = e.to_string();
                            assert!(
                                msg.contains("stopped") || msg.contains("dropped"),
                                "unexpected error under stop: {msg}"
                            );
                            errs += 1;
                        }
                    }
                    if stop_seen.load(Ordering::Relaxed) && errs > 0 {
                        break;
                    }
                }
                (oks, errs)
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(5));
    stop_seen.store(true, Ordering::Relaxed);
    b.stop();
    let mut total_ok = 0usize;
    let mut total_err = 0usize;
    // joins returning at all proves no submitter hung or panicked
    for h in handles {
        let (o, e) = h.join().unwrap();
        total_ok += o;
        total_err += e;
    }
    assert!(total_ok > 0, "some rows should score before the stop");
    let _ = total_err; // may be 0 on a fast machine; cleanliness is asserted per-error
    assert!(b.submit(row(1)).is_err(), "post-stop submits must fail");
}

// ---------------------------------------------------------------------
// Stub stepped protocol + gate (`testutil::Gate`, shared by the
// server-side tests).
// ---------------------------------------------------------------------

struct Stepped {
    rounds: usize,
    /// (step number, gate): that step blocks until the gate opens
    gate: Option<(usize, Gate)>,
}

impl Protocol for Stepped {
    fn name(&self) -> String {
        format!("stepped[{}]", self.rounds)
    }

    fn session(&self, sample: &Sample) -> Box<dyn ProtocolSession> {
        Box::new(SteppedSession {
            truth: sample.query.answer.clone(),
            rounds: self.rounds,
            gate: self.gate.clone(),
            step: 0,
        })
    }
}

struct SteppedSession {
    truth: Answer,
    rounds: usize,
    gate: Option<(usize, Gate)>,
    step: usize,
}

impl ProtocolSession for SteppedSession {
    fn step(&mut self, _rng: &mut Rng) -> Result<SessionEvent> {
        self.step += 1;
        if let Some((gated_step, gate)) = &self.gate {
            if self.step == *gated_step {
                gate.wait();
            }
        }
        if self.step <= self.rounds {
            Ok(SessionEvent::RoundExecuted {
                round: self.step,
                jobs: 1,
                survivors: 0,
            })
        } else {
            let mut ledger = Ledger::default();
            ledger.remote_msg(10, 1);
            Ok(SessionEvent::Finalized(Outcome {
                answer: self.truth.clone(),
                ledger,
                rounds: self.rounds,
                transcript: vec![],
            }))
        }
    }
}

/// A session that yields `Backoff` N times before finalizing — pins the
/// runner's delayed-requeue path end to end.
struct BackoffTimes {
    n: usize,
}

impl Protocol for BackoffTimes {
    fn name(&self) -> String {
        format!("backoff[{}]", self.n)
    }

    fn session(&self, sample: &Sample) -> Box<dyn ProtocolSession> {
        Box::new(BackoffSession {
            remaining: self.n,
            truth: sample.query.answer.clone(),
        })
    }
}

struct BackoffSession {
    remaining: usize,
    truth: Answer,
}

impl ProtocolSession for BackoffSession {
    fn step(&mut self, _rng: &mut Rng) -> Result<SessionEvent> {
        if self.remaining > 0 {
            self.remaining -= 1;
            return Ok(SessionEvent::Backoff);
        }
        Ok(SessionEvent::Finalized(Outcome {
            answer: self.truth.clone(),
            ledger: Ledger::default(),
            rounds: 1,
            transcript: vec![],
        }))
    }
}

#[test]
fn shutdown_with_queued_sessions_fails_them_instead_of_leaking() {
    let runner = SessionRunner::new(1);
    let gate = Gate::default();
    let proto: Arc<dyn Protocol> = Arc::new(Stepped {
        rounds: 3,
        gate: Some((1, gate.clone())),
    });
    let ds = data::micro::multistep_sweep(1, 3, 5);
    // the lone worker blocks inside session A's first step; B and C are
    // queued but never started
    let a = runner.spawn(&proto, &ds.samples[0], Rng::seed_from(1), None);
    let b = runner.spawn(&proto, &ds.samples[1], Rng::seed_from(2), None);
    let c = runner.spawn(&proto, &ds.samples[2], Rng::seed_from(3), None);
    let r2 = Arc::clone(&runner);
    let shutdown = std::thread::spawn(move || r2.shutdown());
    std::thread::sleep(Duration::from_millis(20));
    gate.open(); // let the in-flight step finish so the worker can exit
    shutdown.join().unwrap();
    // every waiter wakes with Failed — nothing leaks, nothing hangs
    for (label, entry) in [("a", &a), ("b", &b), ("c", &c)] {
        assert_eq!(
            entry.wait_done(),
            SessionStatus::Failed,
            "session {label} must be failed by shutdown"
        );
        assert!(
            entry.status_json().contains("shut down"),
            "session {label} must carry the shutdown error"
        );
    }
    assert_eq!(runner.active(), 0);
}

#[test]
fn backed_off_sessions_requeue_with_delay_and_complete() {
    let runner = SessionRunner::new(1);
    let proto: Arc<dyn Protocol> = Arc::new(BackoffTimes { n: 3 });
    let ds = data::micro::multistep_sweep(1, 1, 5);
    let entry = runner.spawn(&proto, &ds.samples[0], Rng::seed_from(1), None);
    assert_eq!(entry.wait_done(), SessionStatus::Done);
    assert_eq!(entry.backoffs(), 3);
    assert_eq!(runner.backoffs_total(), 3);
    assert!(
        entry.status_json().contains("\"backoffs\":3"),
        "status must expose the backoff count: {}",
        entry.status_json()
    );
    runner.shutdown();
}

/// `--lane-weights` parsing rejects zero-weight lanes outright: a lane
/// with weight 0 would accrue no deficit credit and silently starve, so
/// an operator typo must fail at parse time (the CLI warns and keeps the
/// default) instead of shipping a starved lane into production.
#[test]
fn zero_weight_lanes_are_rejected_at_parse_time() {
    assert_eq!(parse_lane_weights("0:1"), None);
    assert_eq!(parse_lane_weights("1:0"), None);
    assert_eq!(parse_lane_weights("0:0"), None);
    assert_eq!(parse_lane_weights("4:1"), Some((4, 1)));
    assert_eq!(parse_lane_weights("1:16"), Some((1, 16)));
}

#[test]
fn terminal_sessions_are_evicted_after_ttl() {
    let runner = SessionRunner::with_config(1, Duration::from_millis(50));
    let proto: Arc<dyn Protocol> = Arc::new(Stepped {
        rounds: 1,
        gate: None,
    });
    let ds = data::micro::multistep_sweep(1, 2, 5);
    let a = runner.spawn(&proto, &ds.samples[0], Rng::seed_from(1), None);
    assert_eq!(a.wait_done(), SessionStatus::Done);
    assert!(runner.get(a.id).is_some(), "pollable before the TTL");
    std::thread::sleep(Duration::from_millis(80));
    // spawning reaps expired terminal entries opportunistically
    let b = runner.spawn(&proto, &ds.samples[1], Rng::seed_from(2), None);
    assert!(
        runner.get(a.id).is_none(),
        "terminal session must be evicted after the TTL (404 afterwards)"
    );
    assert!(runner.evicted_total() >= 1);
    assert_eq!(b.wait_done(), SessionStatus::Done);
    runner.shutdown();
}

// ---------------------------------------------------------------------
// (c) End-to-end admission control: 429 + Retry-After, then success.
// ---------------------------------------------------------------------

#[test]
fn saturated_admission_sheds_with_429_and_a_later_retry_succeeds() {
    let gate = Gate::default();
    let proto: Arc<dyn Protocol> = Arc::new(Stepped {
        rounds: 1,
        gate: Some((1, gate.clone())),
    });
    let ds = data::micro::multistep_sweep(1, 2, 5);
    let mut datasets = HashMap::new();
    datasets.insert("micro".to_string(), ds);
    let mut protocols: HashMap<String, Arc<dyn Protocol>> = HashMap::new();
    protocols.insert("stepped".to_string(), proto);
    let state = Arc::new(ServerState {
        datasets,
        protocols,
        aliases: HashMap::new(),
        factory: None,
        metrics: Arc::new(Metrics::default()),
        seed: 7,
        batcher: None,
        cache: None,
        engine: None,
        sessions: SessionRunner::new(2),
        max_sessions: 1, // tiny on purpose: the second POST must shed
    });
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    let body = r#"{"dataset":"micro","sample":0,"protocol":"stepped"}"#;
    // first session occupies the only slot (its first step parks on the gate)
    let resp = http_post(&addr, "/v1/sessions", body).unwrap();
    let sid = Json::parse(&resp)
        .unwrap()
        .get("session_id")
        .and_then(Json::as_u64)
        .expect("first session admitted");

    // second POST: shed with 429 + Retry-After, never panicking a worker
    let raw = http_post_raw(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":1,"protocol":"stepped"}"#,
    )
    .unwrap();
    assert!(
        raw.starts_with("HTTP/1.1 429"),
        "expected 429 Too Many Requests, got: {raw}"
    );
    assert!(raw.contains("Retry-After: 1"), "missing Retry-After: {raw}");
    assert!(raw.contains("registry full"), "unhelpful shed body: {raw}");

    // the shed request is counted
    let metrics = http_get(&addr, "/metrics").unwrap();
    let m = Json::parse(&metrics).unwrap();
    assert!(m.get("sessions_shed").unwrap().as_u64().unwrap() >= 1);

    // let the first session finish, then the retry must be admitted
    gate.open();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let status = http_get(&addr, &format!("/v1/sessions/{sid}")).unwrap();
        if status.contains("\"done\"") {
            break;
        }
        assert!(Instant::now() < deadline, "first session never finished");
        std::thread::sleep(Duration::from_millis(5));
    }
    let retry = http_post(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":1,"protocol":"stepped"}"#,
    )
    .unwrap();
    let rid = Json::parse(&retry)
        .unwrap()
        .get("session_id")
        .and_then(Json::as_u64)
        .expect("retry admitted after the registry drained");
    // the retried session runs to completion: the worker pool survived
    // the shed unscathed
    let events = http_get(&addr, &format!("/v1/sessions/{rid}/events")).unwrap();
    assert!(events.contains("\"finalized\""), "got: {events}");
}
