//! Deterministic fault-injection suite for the session WAL (DESIGN.md
//! §8, §12). The discipline is the same bit-identity `cache_parity.rs`
//! and `sched_fairness.rs` pin elsewhere: a crash is simulated by
//! truncating (or corrupting) the log at a record boundary, a
//! "restarted server" is a fresh scoring stack + runner recovering the
//! directory, and the assertion is that the recovered run's **entire
//! WAL** — every event, rng checkpoint, snapshot, ledger total, and the
//! final answer — is byte-identical to the uninterrupted run's, for
//! every protocol and every kill point.
//!
//! The whole suite runs against both durability backends: the
//! `MINIONS_WAL_MODE=segmented` env toggle (a CI matrix leg, like
//! `MINIONS_WAL_META`) flips every default runner to the shared
//! group-commit segments, and the `segmented_*` tests below pin the
//! segment-only failure modes (torn segment tails, mid-rotation kills,
//! compaction, legacy-file migration) explicitly so plain `cargo test`
//! covers them too.
//!
//! Run with `--test-threads=1` (the CI `durability` job does): the
//! pseudo-backend stacks are cheap but each case spins its own batcher
//! worker, and serial execution keeps the WAL corpus readable when a
//! failure uploads it.

mod testutil;

use minions::data::Sample;
use minions::protocol::{Protocol, ProtocolKind, ProtocolSession, SessionEvent};
use minions::router::{self, AutoSpec};
use minions::server::session::{CancelOutcome, SessionRunner, SessionStatus, WalMode};
use minions::server::wal::segment::{self, SegmentConfig};
use minions::server::wal::{self, WalMeta};
use minions::util::json::Json;
use minions::util::rng::Rng;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;
use testutil::{
    case_dir, datasets, encode_record_line, factory, protocols, read_wal_lines,
    reframe_segmented, segment_lines_for, segmented_mode, session_lines, spec_for, stack,
    v2_meta_mode, write_session_wal, write_wal, Gate,
};

const SEED: u64 = 11;
const TTL: Duration = Duration::from_secs(600);

/// All five protocol families, plus the forced-two-round MinionS that
/// guarantees a multi-round WAL (the acceptance case).
const SWEEP: [&str; 6] = ["minions-2r", "minions", "minion", "local", "remote", "rag"];

struct Baseline {
    id: u64,
    lines: Vec<String>,
    rng_final: [u64; 4],
    outcome: String,
}

/// The session's WAL identity. In `MINIONS_WAL_META=v2` mode (the CI
/// matrix's second leg) every spec-expressible protocol embeds its spec,
/// so the sweep exercises factory-based recovery; protocols without a
/// spec (the forced-two-round MinionS, ad-hoc stubs) stay on v1 records
/// and keep the registry replay path covered in both modes.
fn wal_meta(proto_key: &str, sample: usize) -> WalMeta {
    WalMeta {
        proto_key: proto_key.to_string(),
        dataset: "micro".to_string(),
        sample,
        spec: if v2_meta_mode() {
            spec_for(proto_key)
        } else {
            None
        },
        routed: None,
    }
}

/// The `body.event.outcome` payload of a WAL's finalized record.
fn finalized_outcome(lines: &[String]) -> String {
    let last = Json::parse(lines.last().expect("nonempty wal")).expect("parse record");
    let body = last.get("body").expect("body");
    assert_eq!(
        body.get("type").and_then(Json::as_str),
        Some("finalized"),
        "last record must be the finalized one: {body}"
    );
    body.get("event")
        .and_then(|e| e.get("outcome"))
        .expect("finalized outcome")
        .to_string()
}

/// Run `proto_key` over sample `sample` to completion on a durable
/// runner; return the full WAL and the terminal rng state.
fn run_baseline(case: &str, proto_key: &str, sample: usize) -> Baseline {
    let dir = case_dir(case);
    let s = stack();
    let protos = protocols(&s);
    let ds = datasets();
    let runner = SessionRunner::with_wal(1, TTL, &dir).unwrap();
    let proto = protos.get(proto_key).unwrap();
    let sample_ref = &ds.get("micro").unwrap().samples[sample];
    let entry = runner.spawn_durable(
        proto,
        sample_ref,
        Rng::seed_from(SEED ^ sample as u64),
        None,
        wal_meta(proto_key, sample),
    );
    assert_eq!(
        entry.wait_done(),
        SessionStatus::Done,
        "{proto_key} baseline must finish: {}",
        entry.status_json()
    );
    let rng_final = entry.rng_state();
    let id = entry.id;
    runner.shutdown();
    s.batcher.stop();
    let lines = session_lines(&dir, id);
    let outcome = finalized_outcome(&lines);
    Baseline {
        id,
        lines,
        rng_final,
        outcome,
    }
}

/// Group-commit knobs for the explicit segmented tests: flush each
/// batch immediately (no grace window), production-default rotation and
/// compaction thresholds.
fn seg_cfg() -> SegmentConfig {
    SegmentConfig {
        commit_interval: Duration::ZERO,
        ..SegmentConfig::default()
    }
}

/// [`run_baseline`], but on an explicitly `mode`-backed runner
/// regardless of the env toggle — the segment-only tests and the
/// legacy-migration tests both need a backend they can rely on.
fn run_baseline_mode(case: &str, proto_key: &str, sample: usize, mode: WalMode) -> Baseline {
    let dir = case_dir(case);
    let s = stack();
    let protos = protocols(&s);
    let ds = datasets();
    let cfg = seg_cfg();
    let runner = SessionRunner::with_wal_mode(1, TTL, &dir, mode, cfg).unwrap();
    let proto = protos.get(proto_key).unwrap();
    let sample_ref = &ds.get("micro").unwrap().samples[sample];
    let entry = runner.spawn_durable(
        proto,
        sample_ref,
        Rng::seed_from(SEED ^ sample as u64),
        None,
        wal_meta(proto_key, sample),
    );
    assert_eq!(
        entry.wait_done(),
        SessionStatus::Done,
        "{proto_key} baseline must finish: {}",
        entry.status_json()
    );
    let rng_final = entry.rng_state();
    let id = entry.id;
    runner.shutdown();
    s.batcher.stop();
    let lines = match mode {
        WalMode::Segmented => segment_lines_for(&dir, id),
        WalMode::PerSession => read_wal_lines(&wal::wal_path(&dir, id)),
    };
    let outcome = finalized_outcome(&lines);
    Baseline {
        id,
        lines,
        rng_final,
        outcome,
    }
}

/// [`recover_dir`], but on an explicitly segmented runner. Record lines
/// are read only after shutdown, once the group committer has drained
/// and any compaction has settled.
fn recover_dir_segmented(
    dir: &Path,
    id: u64,
) -> (
    minions::server::session::RecoveryReport,
    Option<(Vec<String>, [u64; 4])>,
) {
    let s = stack();
    let protos = protocols(&s);
    let ds = datasets();
    let cfg = seg_cfg();
    let runner = SessionRunner::with_wal_mode(1, TTL, dir, WalMode::Segmented, cfg).unwrap();
    let f = factory(&s);
    let report = runner.recover(&ds, &protos, Some(&f), None);
    let rng = if report.resumed > 0 {
        let entry = runner.get(id).expect("recovered session is registered");
        assert_eq!(
            entry.wait_done(),
            SessionStatus::Done,
            "recovered session must finish: {}",
            entry.status_json()
        );
        Some(entry.rng_state())
    } else {
        None
    };
    runner.shutdown();
    s.batcher.stop();
    let result = rng.map(|r| (segment_lines_for(dir, id), r));
    (report, result)
}

/// "Restart the server" over `dir`: fresh stack, recover, drive the
/// resumed session (if any) to completion. Returns the recovery report
/// and, when a session resumed, its final WAL lines + rng state.
fn recover_dir(
    dir: &Path,
    id: u64,
) -> (
    minions::server::session::RecoveryReport,
    Option<(Vec<String>, [u64; 4])>,
) {
    let s = stack();
    let protos = protocols(&s);
    let ds = datasets();
    let runner = SessionRunner::with_wal(1, TTL, dir).unwrap();
    // the factory serves v2 (spec-bearing) metas; v1 metas resolve
    // through the registry regardless
    let f = factory(&s);
    let report = runner.recover(&ds, &protos, Some(&f), None);
    let result = if report.resumed > 0 {
        let entry = runner.get(id).expect("recovered session is registered");
        assert_eq!(
            entry.wait_done(),
            SessionStatus::Done,
            "recovered session must finish: {}",
            entry.status_json()
        );
        let rng = entry.rng_state();
        Some((session_lines(dir, id), rng))
    } else {
        None
    };
    runner.shutdown();
    s.batcher.stop();
    (report, result)
}

/// The property sweep: for each protocol, kill after every record
/// boundary and assert the recovered run is bit-identical to the
/// uninterrupted one — same WAL bytes (events, rng checkpoints,
/// snapshots, ledger, answer), same terminal rng state.
#[test]
fn kill_and_recover_at_every_record_boundary_is_bit_identical() {
    for proto_key in SWEEP {
        let base = run_baseline(&format!("base-{proto_key}"), proto_key, 0);
        let n = base.lines.len();
        assert!(n >= 2, "{proto_key}: wal has meta + finalized at least");
        for cut in 1..n {
            let dir = case_dir(&format!("cut-{proto_key}-{cut}"));
            write_session_wal(&dir, base.id, &base.lines[..cut], None);
            let (report, result) = recover_dir(&dir, base.id);
            assert_eq!(
                report.resumed, 1,
                "{proto_key} cut {cut}: incomplete log must resume"
            );
            let (lines, rng) = result.unwrap();
            assert_eq!(
                lines, base.lines,
                "{proto_key} cut {cut}: recovered WAL must be byte-identical"
            );
            assert_eq!(
                rng, base.rng_final,
                "{proto_key} cut {cut}: rng stream must land on the same state"
            );
            assert_eq!(
                finalized_outcome(&lines),
                base.outcome,
                "{proto_key} cut {cut}: answer/ledger must match"
            );
        }
    }
}

/// The forced-two-round acceptance case really is multi-round: two
/// planned events, at least one executed round, five+ records.
#[test]
fn forced_two_round_baseline_has_the_full_record_sequence() {
    let base = run_baseline("shape-minions-2r", "minions-2r", 0);
    let kinds: Vec<String> = base
        .lines
        .iter()
        .map(|l| {
            let v = Json::parse(l).unwrap();
            let body = v.get("body").unwrap();
            match body.get("type").and_then(Json::as_str).unwrap() {
                "step" => body
                    .get("event")
                    .and_then(|e| e.get("kind"))
                    .and_then(Json::as_str)
                    .unwrap()
                    .to_string(),
                t => t.to_string(),
            }
        })
        .collect();
    assert_eq!(
        kinds,
        vec![
            "meta",
            "planned",
            "round_executed",
            "planned",
            "finalized"
        ],
        "expected the canonical 2-round MinionS record sequence"
    );
}

/// Torn-write simulation: a partial final line (the state a crash
/// mid-append leaves) must be discarded, and recovery from the intact
/// prefix must still converge to the bit-identical baseline. A corrupted
/// byte in the tail record (CRC mismatch) gets the same treatment.
#[test]
fn torn_and_corrupt_tails_recover_like_the_clean_prefix() {
    let base = run_baseline("base-torn", "minions-2r", 1);
    let n = base.lines.len();
    for cut in 1..n {
        // torn: half of the next record made it to disk
        let torn = &base.lines[cut].as_bytes()[..base.lines[cut].len() / 2];
        let dir = case_dir(&format!("torn-{cut}"));
        write_session_wal(&dir, base.id, &base.lines[..cut], Some(torn));
        let (report, result) = recover_dir(&dir, base.id);
        assert_eq!(report.resumed, 1, "torn cut {cut} must resume");
        let (lines, rng) = result.unwrap();
        assert_eq!(lines, base.lines, "torn cut {cut}: bit-identical WAL");
        assert_eq!(rng, base.rng_final, "torn cut {cut}: rng state");

        // corrupt: the last kept record's payload has a flipped byte —
        // its CRC fails, so recovery must fall back to the records
        // before it (never trust a corrupt record)
        if cut >= 2 {
            let mut kept: Vec<String> = base.lines[..cut].to_vec();
            let idx = cut - 1;
            let corrupted = kept[idx].replacen("\"type\":\"step\"", "\"type\":\"steP\"", 1);
            assert_ne!(corrupted, kept[idx], "corruption must actually land");
            kept[idx] = corrupted;
            let dir = case_dir(&format!("corrupt-{cut}"));
            write_session_wal(&dir, base.id, &kept, None);
            let (report, result) = recover_dir(&dir, base.id);
            assert_eq!(report.resumed, 1, "corrupt cut {cut} must resume");
            let (lines, rng) = result.unwrap();
            assert_eq!(lines, base.lines, "corrupt cut {cut}: bit-identical WAL");
            assert_eq!(rng, base.rng_final, "corrupt cut {cut}: rng state");
        }
    }
}

/// The silent-resurrection guard: a WAL whose last record is terminal
/// (finalized here, cancelled below) is counted, deleted, and never
/// re-enqueued.
#[test]
fn terminal_logs_are_skipped_not_resurrected() {
    let base = run_baseline("base-terminal", "minions-2r", 2);
    // finalized log
    let dir = case_dir("terminal-finalized");
    write_session_wal(&dir, base.id, &base.lines, None);
    let s = stack();
    let runner = SessionRunner::with_wal(1, TTL, &dir).unwrap();
    let report = runner.recover(&datasets(), &protocols(&s), None, None);
    assert_eq!(report.resumed, 0);
    assert_eq!(report.skipped_terminal, 1);
    assert_eq!(runner.replay_skipped_terminal(), 1);
    assert!(runner.get(base.id).is_none(), "must not re-register");
    assert_eq!(runner.active(), 0, "must not consume a slot");
    if !segmented_mode() {
        // per-session cleanup is eager; segmented records wait for
        // compaction to reclaim their bytes
        let path = wal::wal_path(&dir, base.id);
        assert!(!path.exists(), "terminal log is deleted after the skip");
    }
    runner.shutdown();
    s.batcher.stop();

    // cancelled log: mid-run prefix + a cancelled terminal record
    let dir = case_dir("terminal-cancelled");
    let keep = 2.min(base.lines.len() - 1);
    let mut lines: Vec<String> = base.lines[..keep].to_vec();
    lines.push(encode_record_line(base.id, keep as u64, &wal::cancelled_body()));
    write_session_wal(&dir, base.id, &lines, None);
    let s = stack();
    let runner = SessionRunner::with_wal(1, TTL, &dir).unwrap();
    let report = runner.recover(&datasets(), &protocols(&s), None, None);
    assert_eq!(report.resumed, 0);
    assert_eq!(report.skipped_terminal, 1);
    assert!(runner.get(base.id).is_none(), "cancelled session never reappears");
    if !segmented_mode() {
        assert!(!wal::wal_path(&dir, base.id).exists());
    }
    runner.shutdown();
    s.batcher.stop();
}

// ---------------------------------------------------------------------
// Backoff records: a saturated-scheduler streak writes exactly one
// (coalesced) WAL record, and a log ending in a backoff record resumes.
// ---------------------------------------------------------------------

/// Yields `Backoff` N times, then finalizes with a fixed answer.
struct BackoffTimes {
    n: usize,
}

impl Protocol for BackoffTimes {
    fn name(&self) -> String {
        format!("backoff[{}]", self.n)
    }

    fn session(&self, sample: &Sample) -> Box<dyn ProtocolSession> {
        Box::new(BackoffSession {
            remaining: self.n,
            truth: sample.query.answer.clone(),
        })
    }
}

struct BackoffSession {
    remaining: usize,
    truth: minions::data::Answer,
}

impl ProtocolSession for BackoffSession {
    fn step(&mut self, _rng: &mut Rng) -> anyhow::Result<SessionEvent> {
        if self.remaining > 0 {
            self.remaining -= 1;
            return Ok(SessionEvent::Backoff);
        }
        Ok(SessionEvent::Finalized(minions::protocol::Outcome {
            answer: self.truth.clone(),
            ledger: minions::cost::Ledger::default(),
            rounds: 1,
            transcript: vec![],
        }))
    }
}

#[test]
fn backoff_streaks_coalesce_to_one_record_and_backoff_tails_resume() {
    let dir = case_dir("backoff-coalesce");
    let proto: Arc<dyn Protocol> = Arc::new(BackoffTimes { n: 4 });
    let ds = datasets();
    let sample = &ds.get("micro").unwrap().samples[0];
    let runner = SessionRunner::with_wal(1, TTL, &dir).unwrap();
    let entry = runner.spawn_durable(
        &proto,
        sample,
        Rng::seed_from(3),
        None,
        wal_meta("backoff", 0),
    );
    assert_eq!(entry.wait_done(), SessionStatus::Done);
    assert_eq!(entry.backoffs(), 4);
    // the WAL satellite of the status body: a session whose log opened
    // cleanly reports itself durable
    let status = Json::parse(&entry.status_json()).unwrap();
    assert_eq!(status.get("durable").and_then(Json::as_bool), Some(true));
    let id = entry.id;
    runner.shutdown();

    // 4 backed-off retries coalesced into ONE backoff record:
    // meta, backoff, finalized
    let lines = session_lines(&dir, id);
    let kinds: Vec<String> = lines
        .iter()
        .map(|l| {
            let v = Json::parse(l).unwrap();
            let body = v.get("body").unwrap();
            match body.get("type").and_then(Json::as_str).unwrap() {
                "step" => body
                    .get("event")
                    .and_then(|e| e.get("kind"))
                    .and_then(Json::as_str)
                    .unwrap()
                    .to_string(),
                t => t.to_string(),
            }
        })
        .collect();
    assert_eq!(kinds, vec!["meta", "backoff", "finalized"], "{lines:?}");

    // a log whose last record is the backoff checkpoint must resume
    let dir2 = case_dir("backoff-tail");
    write_session_wal(&dir2, id, &lines[..2], None);
    let runner = SessionRunner::with_wal(1, TTL, &dir2).unwrap();
    let s = stack();
    let mut protos = protocols(&s);
    protos.insert("backoff".into(), Arc::new(BackoffTimes { n: 0 }));
    let report = runner.recover(&ds, &protos, None, None);
    assert_eq!(report.resumed, 1, "backoff tail must resume");
    let entry = runner.get(id).expect("registered");
    assert_eq!(entry.wait_done(), SessionStatus::Done);
    // the replayed backoff record is counted in the entry's stats
    assert_eq!(entry.backoffs(), 1);
    runner.shutdown();
    s.batcher.stop();
}

// ---------------------------------------------------------------------
// End-to-end cancellation durability: cancel a live durable session,
// restart, and assert it stays dead.
// ---------------------------------------------------------------------

/// Endless stub protocol whose first step signals `entered` and then
/// parks on `release` — the deterministic "mid-step" window the cancel
/// path needs.
struct Parked {
    entered: Gate,
    release: Gate,
}

impl Protocol for Parked {
    fn name(&self) -> String {
        "parked".into()
    }

    fn session(&self, _sample: &Sample) -> Box<dyn ProtocolSession> {
        Box::new(ParkedSession {
            entered: self.entered.clone(),
            release: self.release.clone(),
            step: 0,
        })
    }
}

struct ParkedSession {
    entered: Gate,
    release: Gate,
    step: usize,
}

impl ProtocolSession for ParkedSession {
    fn step(&mut self, _rng: &mut Rng) -> anyhow::Result<SessionEvent> {
        self.step += 1;
        if self.step == 1 {
            self.entered.open();
            self.release.wait();
        }
        Ok(SessionEvent::RoundExecuted {
            round: self.step,
            jobs: 1,
            survivors: 0,
        })
    }
}

#[test]
fn cancelled_durable_session_never_reappears_after_restart() {
    let dir = case_dir("cancel-live");
    let entered = Gate::default();
    let release = Gate::default();
    let proto: Arc<dyn Protocol> = Arc::new(Parked {
        entered: entered.clone(),
        release: release.clone(),
    });
    let ds = datasets();
    let sample = &ds.get("micro").unwrap().samples[0];
    let runner = SessionRunner::with_wal(1, TTL, &dir).unwrap();
    let entry = runner.spawn_durable(
        &proto,
        sample,
        Rng::seed_from(1),
        None,
        wal_meta("parked", 0),
    );
    // the worker is provably inside step 1 (it opened `entered` and is
    // parked on `release`): this cancel takes the mid-step flag path —
    // the conversion happens between steps, after the in-flight step's
    // record is persisted
    entered.wait();
    assert_eq!(runner.cancel(entry.id), Some(CancelOutcome::Cancelling));
    release.open();
    assert_eq!(entry.wait_done(), SessionStatus::Cancelled);
    assert_eq!(runner.active(), 0, "cancel must free the slot");
    assert_eq!(runner.cancelled_total(), 1);
    // cancelling again: documented no-op
    assert_eq!(runner.cancel(entry.id), Some(CancelOutcome::AlreadyTerminal));
    let id = entry.id;
    runner.shutdown();

    // the WAL ends with the cancelled record
    let lines = session_lines(&dir, id);
    let last = Json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(
        last.get("body").and_then(|b| b.get("type")).and_then(Json::as_str),
        Some("cancelled"),
        "terminal record must be the cancel: {lines:?}"
    );

    // restart: the cancelled session must not be resurrected
    let s = stack();
    let runner = SessionRunner::with_wal(1, TTL, &dir).unwrap();
    let mut protos = protocols(&s);
    protos.insert(
        "parked".into(),
        Arc::new(Parked {
            entered: Gate::default(),
            release: Gate::default(),
        }),
    );
    let report = runner.recover(&ds, &protos, None, None);
    assert_eq!(report.resumed, 0);
    assert_eq!(report.skipped_terminal, 1);
    assert!(runner.get(id).is_none());
    runner.shutdown();
    s.batcher.stop();
}

// ---------------------------------------------------------------------
// Segment-only failure modes (DESIGN.md §12), pinned explicitly so a
// plain `cargo test` covers the segmented backend even when the
// MINIONS_WAL_MODE matrix leg is not active.
// ---------------------------------------------------------------------

/// A torn tail inside a shared segment — the state a crash mid
/// group-commit leaves — is discarded, and recovery from the intact
/// prefix converges to the bit-identical baseline.
#[test]
fn segmented_torn_segment_tail_recovers_bit_identical() {
    let base = run_baseline_mode("seg-base-torn", "minions-2r", 1, WalMode::Segmented);
    let n = base.lines.len();
    for cut in 1..n {
        let torn = &base.lines[cut].as_bytes()[..base.lines[cut].len() / 2];
        let dir = case_dir(&format!("seg-torn-{cut}"));
        write_wal(&segment::segment_path(&dir, 0), &base.lines[..cut], Some(torn));
        let (report, result) = recover_dir_segmented(&dir, base.id);
        assert_eq!(report.resumed, 1, "seg torn cut {cut} must resume");
        let (lines, rng) = result.unwrap();
        assert_eq!(lines, base.lines, "seg torn cut {cut}: bit-identical records");
        assert_eq!(rng, base.rng_final, "seg torn cut {cut}: rng state");
    }
}

/// A kill mid-rotation: the crash lands right after rotation created
/// the next segment file, so the intact records are split across sealed
/// segments and the fresh active segment holds only a torn first
/// record. Recovery must stitch the global order back together; the
/// resumed continuation (which lands in the active segment, beyond
/// compaction's reach) must be byte-identical to the baseline's suffix.
#[test]
fn segmented_mid_rotation_kill_recovers() {
    let base = run_baseline_mode("seg-base-rot", "minions-2r", 0, WalMode::Segmented);
    let n = base.lines.len();
    assert!(n >= 3, "multi-round baseline expected");
    for cut in 2..n {
        let split = cut / 2;
        let torn = &base.lines[cut].as_bytes()[..base.lines[cut].len() / 2];
        let dir = case_dir(&format!("seg-rot-{cut}"));
        write_wal(&segment::segment_path(&dir, 0), &base.lines[..split], None);
        write_wal(&segment::segment_path(&dir, 1), &base.lines[split..cut], None);
        write_wal(&segment::segment_path(&dir, 2), &[], Some(torn));
        let (report, result) = recover_dir_segmented(&dir, base.id);
        assert_eq!(report.resumed, 1, "seg rot cut {cut} must resume");
        let (lines, rng) = result.unwrap();
        assert_eq!(
            &lines[lines.len() - (n - cut)..],
            &base.lines[cut..],
            "seg rot cut {cut}: continuation is byte-identical"
        );
        assert_eq!(rng, base.rng_final, "seg rot cut {cut}: rng state");
        assert_eq!(finalized_outcome(&lines), base.outcome);
    }
}

/// Recovery after compaction: a sealed segment holding only a finished
/// session is fully dead once scanned; a restart must collect it while
/// the incomplete session resumes, and the resumed continuation must
/// still be byte-identical.
#[test]
fn segmented_compaction_collects_dead_segments_and_recovery_converges() {
    let base = run_baseline_mode("seg-base-compact", "minions-2r", 2, WalMode::Segmented);
    let n = base.lines.len();
    let cut = 2;
    assert!(n > cut, "need records beyond the cut");
    let live_id = base.id + 1;
    let live = reframe_segmented(&base.lines, live_id);

    // crash state: segment 0 = the finished session (every byte dead
    // once scanned), segment 1 (active) = the live session's prefix
    let dir = case_dir("seg-compact");
    write_wal(&segment::segment_path(&dir, 0), &base.lines, None);
    write_wal(&segment::segment_path(&dir, 1), &live[..cut], None);

    let s = stack();
    let protos = protocols(&s);
    let ds = datasets();
    let cfg = seg_cfg();
    let runner = SessionRunner::with_wal_mode(1, TTL, &dir, WalMode::Segmented, cfg).unwrap();
    let f = factory(&s);
    let report = runner.recover(&ds, &protos, Some(&f), None);
    assert_eq!(report.skipped_terminal, 1, "finished session must not resurrect");
    assert_eq!(report.resumed, 1, "live session must resume");
    let entry = runner.get(live_id).expect("live session registered");
    assert_eq!(entry.wait_done(), SessionStatus::Done);
    let rng = entry.rng_state();
    runner.shutdown();
    let stats = runner.wal_stats();
    assert!(
        stats.segmented.expect("segmented stats").compactions >= 1,
        "fully dead segment must be collected"
    );
    s.batcher.stop();

    let lines = segment_lines_for(&dir, live_id);
    assert!(
        segment_lines_for(&dir, base.id).is_empty(),
        "the finished session's records are reclaimed"
    );
    assert_eq!(
        &lines[lines.len() - (n - cut)..],
        &live[cut..],
        "resumed continuation is byte-identical"
    );
    assert_eq!(rng, base.rng_final);
    assert_eq!(finalized_outcome(&lines), base.outcome);

    // a second restart: the collected session stays gone, the completed
    // one is terminal — nothing resumes
    let (report2, result2) = recover_dir_segmented(&dir, live_id);
    assert_eq!(report2.resumed, 0);
    assert_eq!(report2.skipped_terminal, 1);
    assert!(result2.is_none());
}

/// Legacy migration: per-session WAL files cut mid-run are what an
/// upgraded server finds on its first segmented boot. Recovery imports
/// the prefix into the shared segments as one commit batch, deletes the
/// legacy file, resumes the session, and converges to the per-session
/// baseline's records re-framed as segment records.
#[test]
fn legacy_per_session_wal_migrates_into_segments_and_converges() {
    let base = run_baseline_mode("migrate-base", "minions-2r", 0, WalMode::PerSession);
    let n = base.lines.len();
    for cut in 1..n {
        let dir = case_dir(&format!("migrate-{cut}"));
        write_wal(&wal::wal_path(&dir, base.id), &base.lines[..cut], None);
        let (report, result) = recover_dir_segmented(&dir, base.id);
        assert_eq!(report.resumed, 1, "migrate cut {cut} must resume");
        assert!(
            !wal::wal_path(&dir, base.id).exists(),
            "legacy file is deleted once its records are in the segments"
        );
        let (lines, rng) = result.unwrap();
        assert_eq!(
            lines,
            reframe_segmented(&base.lines, base.id),
            "migrate cut {cut}: records"
        );
        assert_eq!(rng, base.rng_final, "migrate cut {cut}: rng state");
    }
}

// ---------------------------------------------------------------------
// Auto-routed sessions (DESIGN.md §14): the v3 meta embeds BOTH the
// resolved concrete spec and the router's decision payload, so
// kill-and-recover needs neither a registry entry nor a re-probe — the
// replayed session runs the originally routed rung byte for byte, and
// the restored status body re-surfaces the original decision verbatim.
// Auto metas are v3 regardless of the MINIONS_WAL_META matrix leg (that
// env toggle covers legacy registry protocols, not routed sessions).
// ---------------------------------------------------------------------

/// Route sample `sample` through the real probe + cost function, assert
/// the policy deterministically picked `expect`, run the routed session
/// to completion on a durable runner, and return the baseline plus the
/// decision's canonical payload bytes.
fn run_auto_baseline(
    case: &str,
    auto_json: &str,
    expect: ProtocolKind,
    sample: usize,
) -> (Baseline, String) {
    let auto = AutoSpec::parse(auto_json).unwrap();
    let dir = case_dir(case);
    let s = stack();
    let f = factory(&s);
    let ds = datasets();
    let sample_ref = &ds.get("micro").unwrap().samples[sample];
    let decision =
        router::route_sample(&auto, sample_ref, &s.local, &router::Signals::idle()).unwrap();
    assert_eq!(decision.chosen.kind, expect, "{:?}", decision.scores);
    let routed_bytes = decision.to_json().to_string();
    let proto = f.resolve(&decision.chosen).unwrap();
    let runner = SessionRunner::with_wal(1, TTL, &dir).unwrap();
    let meta = WalMeta {
        proto_key: format!("spec:{:016x}", decision.chosen.fingerprint()),
        dataset: "micro".to_string(),
        sample,
        spec: Some(decision.chosen.clone()),
        routed: Some(decision.to_json()),
    };
    let entry = runner.spawn_durable(
        &proto,
        sample_ref,
        Rng::seed_from(SEED ^ sample as u64),
        None,
        meta,
    );
    assert_eq!(
        entry.wait_done(),
        SessionStatus::Done,
        "auto baseline must finish: {}",
        entry.status_json()
    );
    // the live entry already surfaces the decision on its status body
    let status = Json::parse(&entry.status_json()).unwrap();
    assert_eq!(
        status.get("routed").map(|r| r.to_string()),
        Some(routed_bytes.clone())
    );
    let rng_final = entry.rng_state();
    let id = entry.id;
    runner.shutdown();
    s.batcher.stop();
    let lines = session_lines(&dir, id);
    // the meta record is v3: resolved spec AND routing decision embedded
    let meta_rec = Json::parse(&lines[0]).unwrap();
    let body = meta_rec.get("body").unwrap();
    assert_eq!(body.get("version").and_then(Json::as_u64), Some(3));
    assert_eq!(
        body.get("spec").unwrap().to_string(),
        decision.chosen.canonical_string()
    );
    assert_eq!(body.get("routed").unwrap().to_string(), routed_bytes);
    let outcome = finalized_outcome(&lines);
    (
        Baseline {
            id,
            lines,
            rng_final,
            outcome,
        },
        routed_bytes,
    )
}

/// The durability matrix's auto rows: an auto spec routed to MinionS
/// and one routed to LocalOnly, each killed at every record boundary
/// and recovered with an EMPTY protocol registry — the v3 meta alone
/// (resolved spec + persisted decision) must reproduce the
/// uninterrupted run byte for byte, without re-running the probe.
#[test]
fn auto_routed_sessions_recover_bit_identical_with_an_empty_registry() {
    // quality-first over {local, minions} always escalates to MinionS
    // (its estimate dominates LocalOnly's at every difficulty);
    // cost-first over the full ladder always stays local (the only
    // zero-dollar rung) — both decisions are deterministic in the
    // probe's features.
    let cases = [
        (
            "minions",
            r#"{"kind":"auto","local":"llama-3b","route_weights":"0:0:1","allowed":["local","minions"]}"#,
            ProtocolKind::Minions,
        ),
        (
            "local",
            r#"{"kind":"auto","local":"llama-3b","route_weights":"0:1:0"}"#,
            ProtocolKind::LocalOnly,
        ),
    ];
    for (tag, auto_json, expect) in cases {
        let (base, routed_bytes) =
            run_auto_baseline(&format!("auto-base-{tag}"), auto_json, expect, 0);
        let n = base.lines.len();
        assert!(n >= 2, "auto-{tag}: wal has meta + finalized at least");
        for cut in 1..n {
            let dir = case_dir(&format!("auto-cut-{tag}-{cut}"));
            write_session_wal(&dir, base.id, &base.lines[..cut], None);
            let s = stack();
            let f = factory(&s);
            let runner = SessionRunner::with_wal(1, TTL, &dir).unwrap();
            let empty: HashMap<String, Arc<dyn Protocol>> = HashMap::new();
            let report = runner.recover(&datasets(), &empty, Some(&f), None);
            assert_eq!(
                report.resumed, 1,
                "auto-{tag} cut {cut}: the v3 meta alone must resume"
            );
            let entry = runner.get(base.id).expect("recovered under its original id");
            assert_eq!(entry.wait_done(), SessionStatus::Done);
            // the restored status body re-surfaces the persisted
            // decision verbatim and names the resolved rung, not "auto"
            let status = Json::parse(&entry.status_json()).unwrap();
            assert_eq!(
                status.get("routed").map(|r| r.to_string()),
                Some(routed_bytes.clone()),
                "auto-{tag} cut {cut}: decision must replay, never re-probe"
            );
            assert_ne!(
                status.get("protocol").and_then(Json::as_str),
                Some("auto"),
                "status names the resolved rung"
            );
            assert_eq!(
                entry.rng_state(),
                base.rng_final,
                "auto-{tag} cut {cut}: rng stream must land on the same state"
            );
            runner.shutdown();
            s.batcher.stop();
            let lines = session_lines(&dir, base.id);
            assert_eq!(
                lines, base.lines,
                "auto-{tag} cut {cut}: recovered WAL must be byte-identical"
            );
            assert_eq!(finalized_outcome(&lines), base.outcome);
        }
    }
}

/// The checked-in v1 fixture survives the backend upgrade too: a
/// segmented boot over a state dir holding `session-901.wal` imports
/// it, preserves its v1 meta record, and resumes it.
#[test]
fn checked_in_v1_fixture_migrates_into_segments() {
    const FIX_ID: u64 = 901;
    let dir = case_dir("seg-v1-fixture");
    let fixture = include_str!("fixtures/session-901.wal");
    std::fs::write(wal::wal_path(&dir, FIX_ID), fixture).expect("install fixture");
    let s = stack();
    let ds = datasets();
    let mut protos = protocols(&s);
    protos.insert("fixture".into(), Arc::new(BackoffTimes { n: 0 }));
    let cfg = seg_cfg();
    let runner = SessionRunner::with_wal_mode(1, TTL, &dir, WalMode::Segmented, cfg).unwrap();
    let report = runner.recover(&ds, &protos, None, None);
    assert_eq!(report.resumed, 1, "fixture must migrate and resume");
    assert!(
        !wal::wal_path(&dir, FIX_ID).exists(),
        "legacy fixture file replaced by segment records"
    );
    let entry = runner.get(FIX_ID).expect("fixture session registered");
    assert_eq!(entry.wait_done(), SessionStatus::Done);
    runner.shutdown();
    s.batcher.stop();
    let lines = segment_lines_for(&dir, FIX_ID);
    assert!(lines.len() >= 2, "completion appended records");
    let meta = Json::parse(&lines[0]).unwrap();
    let body = meta.get("body").expect("meta body");
    assert_eq!(
        body.get("version").and_then(Json::as_u64),
        Some(1),
        "v1 meta preserved through migration"
    );
}
