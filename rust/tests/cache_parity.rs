//! Chunk-cache parity tests (artifact-free: deterministic pseudo backend
//! + stub manifest, so these run in every environment).
//!
//! The cache's whole contract is that it is *invisible* to results: a
//! cached score vector is bit-identical to a recomputed one, and all
//! stochastic post-processing happens downstream with the per-sample rng.
//! These tests pin that down:
//! - with-cache vs no-cache runs are **bit-identical** (scores, accuracy
//!   bits, ledgers, per-sample outcomes) on every dataset×protocol pair;
//! - eviction churn under a tiny `--cache-capacity`-style bound (2
//!   entries) never changes outcomes either;
//! - a warmed cache actually short-circuits scoring: re-running a
//!   dataset adds zero batcher dispatches while producing identical
//!   results.

use anyhow::Result;
use minions::cache::ChunkCache;
use minions::data::{self, Dataset};
use minions::eval::{run_protocol, RunResult};
use minions::model::{local, remote, LocalLm, RemoteLm};
use minions::protocol::{LocalOnly, Minion, MinionS, MinionsConfig, Protocol, RemoteOnly};
use minions::rag::{Rag, Retriever};
use minions::runtime::{Backend, EmbedRequest, Manifest, ScoreRequest, ScoreResponse};
use minions::sched::DynamicBatcher;
use minions::vocab::{BATCH, CHUNK, QLEN};
use std::sync::Arc;
use std::time::Duration;

/// SplitMix64-style mixer for the pseudo scorer.
fn mix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic, content-sensitive, row-independent scorer (same
/// construction as `tests/parallel_eval.rs`).
struct PseudoBackend;

impl Backend for PseudoBackend {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse> {
        let mut scores = vec![-1.0e30f32; BATCH * CHUNK];
        let mut lse = vec![0f32; BATCH];
        for b in 0..BATCH {
            let q0 = req.q_tokens[b * QLEN] as u64;
            let q1 = req.q_tokens[b * QLEN + 1] as u64;
            for c in 0..CHUNK {
                if req.c_mask[b * CHUNK + c] == 0.0 {
                    continue;
                }
                let t = req.c_tokens[b * CHUNK + c] as u64;
                let h = mix(
                    q0 ^ (q1 << 16) ^ (t << 32) ^ ((c as u64) << 48) ^ ((req.d as u64) << 60),
                );
                scores[b * CHUNK + c] = ((h >> 11) as f64 / (1u64 << 53) as f64 * 1.5) as f32;
            }
            lse[b] = 1.0;
        }
        Ok(ScoreResponse { scores, lse })
    }

    fn embed(&self, _req: EmbedRequest) -> Result<Vec<f32>> {
        unimplemented!("parity pairs avoid the dense retriever")
    }

    fn name(&self) -> &'static str {
        "pseudo"
    }
}

struct Stack {
    batcher: Arc<DynamicBatcher>,
    local: Arc<LocalLm>,
    remote: Arc<RemoteLm>,
}

fn stack(cache: Option<Arc<ChunkCache>>) -> Stack {
    let batcher = DynamicBatcher::new(Arc::new(PseudoBackend), Duration::from_millis(2));
    let manifest = Manifest::stub_for_tests(&[64, 128, 256, 1024], vec![1.0, 0.5, 0.25]);
    let local = Arc::new(
        LocalLm::with_cache(
            Arc::clone(&batcher),
            &manifest,
            local::LLAMA_3B,
            cache.clone(),
        )
        .unwrap(),
    );
    let remote = Arc::new(
        RemoteLm::with_cache(Arc::clone(&batcher), &manifest, remote::GPT_4O, cache).unwrap(),
    );
    Stack {
        batcher,
        local,
        remote,
    }
}

/// Every protocol the scoring path serves (the dense retriever needs the
/// embed artifact, so RAG runs lexical here).
fn protocols(s: &Stack) -> Vec<Arc<dyn Protocol>> {
    vec![
        Arc::new(LocalOnly::new(Arc::clone(&s.local))),
        Arc::new(RemoteOnly::new(Arc::clone(&s.remote))),
        Arc::new(Minion::new(Arc::clone(&s.local), Arc::clone(&s.remote), 3)),
        Arc::new(MinionS::new(
            Arc::clone(&s.local),
            Arc::clone(&s.remote),
            MinionsConfig::default(),
        )),
        Arc::new(Rag::new(
            Arc::clone(&s.remote),
            Arc::new(PseudoBackend),
            Retriever::Bm25,
            4,
        )),
    ]
}

fn datasets() -> Vec<Dataset> {
    vec![
        data::generate("finance", 4, 13),
        data::generate("health", 4, 13),
        data::generate("qasper", 4, 13),
        data::generate("books", 2, 13),
        data::micro::multistep_sweep(2, 4, 13),
        data::micro::context_sweep(3, 4, 13),
    ]
}

fn assert_identical(a: &RunResult, b: &RunResult, label: &str) {
    assert_eq!(a.scores, b.scores, "{label}: scores diverged");
    assert_eq!(
        a.accuracy.to_bits(),
        b.accuracy.to_bits(),
        "{label}: accuracy diverged"
    );
    assert_eq!(a.cost.total, b.cost.total, "{label}: ledger diverged");
    assert_eq!(a.mean_rounds, b.mean_rounds, "{label}: rounds diverged");
    for (i, (x, y)) in a.outcomes.iter().zip(&b.outcomes).enumerate() {
        assert_eq!(x.answer, y.answer, "{label}: answer {i} diverged");
        assert_eq!(x.ledger, y.ledger, "{label}: ledger {i} diverged");
        assert_eq!(x.rounds, y.rounds, "{label}: rounds {i} diverged");
    }
}

#[test]
fn cached_runs_are_bit_identical_on_every_dataset_protocol_pair() {
    let baseline = stack(None);
    let cached = stack(Some(ChunkCache::new(4096)));
    // tiny bound: constant eviction churn must be invisible too
    let tiny = stack(Some(ChunkCache::new(2)));
    for ds in datasets() {
        for ((p0, p1), p2) in protocols(&baseline)
            .into_iter()
            .zip(protocols(&cached))
            .zip(protocols(&tiny))
        {
            let label = format!("{} on {}", p0.name(), ds.name);
            let r0 = run_protocol(p0.as_ref(), &ds, 29, true).unwrap();
            let r1 = run_protocol(p1.as_ref(), &ds, 29, true).unwrap();
            let r2 = run_protocol(p2.as_ref(), &ds, 29, true).unwrap();
            assert_identical(&r0, &r1, &format!("{label} [cache 4096]"));
            assert_identical(&r0, &r2, &format!("{label} [cache 2]"));
        }
    }
    baseline.batcher.stop();
    cached.batcher.stop();
    tiny.batcher.stop();
}

#[test]
fn warm_cache_skips_scoring_entirely_and_stays_identical() {
    let cache = ChunkCache::new(8192);
    let s = stack(Some(Arc::clone(&cache)));
    let proto = MinionS::new(
        Arc::clone(&s.local),
        Arc::clone(&s.remote),
        MinionsConfig::default(),
    );
    let ds = data::generate("finance", 6, 41);

    let cold = run_protocol(&proto, &ds, 7, true).unwrap();
    let after_cold = s.batcher.snapshot();
    assert!(after_cold.dispatches > 0, "cold run must score");

    let warm = run_protocol(&proto, &ds, 7, true).unwrap();
    let after_warm = s.batcher.snapshot();
    assert_identical(&cold, &warm, "warm re-run");
    assert_eq!(
        after_warm.dispatches, after_cold.dispatches,
        "warm run must add zero batcher dispatches"
    );
    assert!(
        after_warm.cached_rows > after_cold.cached_rows,
        "warm rows must be recorded as cache-skipped"
    );
    let snap = cache.snapshot();
    assert!(snap.hits > 0, "expected hits, got {snap}");
    s.batcher.stop();
}

#[test]
fn eviction_under_tiny_capacity_recomputes_but_never_diverges() {
    // capacity 2 on a workload with dozens of distinct rows: essentially
    // every lookup misses and half the inserts evict — a worst case for
    // any accidental key collision or stale-entry bug
    let cache = ChunkCache::new(2);
    let s = stack(Some(Arc::clone(&cache)));
    let baseline = stack(None);
    let ds = data::micro::context_sweep(4, 6, 17);
    let p_tiny = MinionS::new(
        Arc::clone(&s.local),
        Arc::clone(&s.remote),
        MinionsConfig::default(),
    );
    let p_base = MinionS::new(
        Arc::clone(&baseline.local),
        Arc::clone(&baseline.remote),
        MinionsConfig::default(),
    );
    for seed in [3u64, 5, 7] {
        let a = run_protocol(&p_base, &ds, seed, true).unwrap();
        let b = run_protocol(&p_tiny, &ds, seed, true).unwrap();
        assert_identical(&a, &b, &format!("tiny-capacity seed {seed}"));
    }
    let snap = cache.snapshot();
    assert!(snap.evictions > 0, "tiny cache must churn, got {snap}");
    assert!(cache.len() <= 2, "bound violated: {}", cache.len());
    s.batcher.stop();
    baseline.batcher.stop();
}
