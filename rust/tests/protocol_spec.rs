//! Typed protocol-spec API integration tests (artifact-free, on the
//! `testutil` pseudo-backend stack):
//!
//! - equal specs — whatever JSON key order or irrelevant fields they
//!   arrived with — share ONE factory-cached protocol instance;
//! - a session started from an inline spec, killed mid-run, recovers on
//!   reboot from its WAL v2 meta record **alone**: the protocol
//!   registry handed to recovery is empty, the embedded canonical spec
//!   plus the factory rebuild everything, and the recovered run is
//!   byte-identical to the uninterrupted one;
//! - a checked-in WAL v1 meta record (fixture bytes, never regenerated)
//!   still recovers through the registry path, alongside a v2 log in
//!   the same state dir, with the fixture's bytes preserved verbatim
//!   and the completion deterministic.

mod testutil;

use anyhow::Result;
use minions::cost::Ledger;
use minions::data::Sample;
use minions::protocol::{OneShotSession, Outcome, Protocol, ProtocolSession, ProtocolSpec};
use minions::server::session::{SessionRunner, SessionStatus};
use minions::server::wal::{self, WalMeta};
use minions::util::json::Json;
use minions::util::rng::Rng;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;
use testutil::{case_dir, datasets, factory, read_wal_lines, stack, write_wal};

const TTL: Duration = Duration::from_secs(600);

/// The WAL identity an inline-spec server session gets: a fingerprint
/// key plus the embedded spec (v2 meta).
fn spec_meta(spec: &ProtocolSpec, sample: usize) -> WalMeta {
    WalMeta {
        proto_key: format!("spec:{:016x}", spec.fingerprint()),
        dataset: "micro".to_string(),
        sample,
        spec: Some(spec.clone()),
        routed: None,
    }
}

#[test]
fn equal_specs_share_one_factory_cached_instance() {
    let s = stack();
    let f = factory(&s);
    let a = f.resolve(&ProtocolSpec::minions("llama-3b", "gpt-4o")).unwrap();
    // different key order on the wire, same canonical spec
    let reordered =
        ProtocolSpec::parse(r#"{"remote":"gpt-4o","kind":"minions","local":"llama-3b"}"#)
            .unwrap();
    let b = f.resolve(&reordered).unwrap();
    assert!(Arc::ptr_eq(&a, &b), "equal specs must share one instance");
    // a knob the kind ignores does not fork the instance
    let widened =
        ProtocolSpec::parse(r#"{"kind":"minions","local":"llama-3b","top_k":5}"#).unwrap();
    let c = f.resolve(&widened).unwrap();
    assert!(Arc::ptr_eq(&a, &c), "irrelevant knobs are not identity");
    // a different rung is a different protocol
    let d = f.resolve(&ProtocolSpec::minions("llama-1b", "gpt-4o")).unwrap();
    assert!(!Arc::ptr_eq(&a, &d));
    assert_eq!(f.resolved_count(), 2, "exactly two distinct resolutions");
    s.batcher.stop();
}

/// Acceptance: kill an inline-spec session at every record boundary and
/// recover with an EMPTY protocol registry — the v2 meta's embedded spec
/// plus the factory must reproduce the uninterrupted run byte for byte.
#[test]
fn v2_spec_session_recovers_with_an_empty_registry() {
    let spec = ProtocolSpec::minions("llama-3b", "gpt-4o");
    let ds = datasets();
    let sample = &ds.get("micro").unwrap().samples[0];

    // uninterrupted durable baseline
    let dir = case_dir("spec-v2-base");
    let s = stack();
    let f = factory(&s);
    let proto = f.resolve(&spec).unwrap();
    let runner = SessionRunner::with_wal(1, TTL, &dir).unwrap();
    let entry = runner.spawn_durable(
        &proto,
        sample,
        Rng::seed_from(11),
        None,
        spec_meta(&spec, 0),
    );
    assert_eq!(entry.wait_done(), SessionStatus::Done, "{}", entry.status_json());
    let id = entry.id;
    let rng_final = entry.rng_state();
    runner.shutdown();
    s.batcher.stop();
    let base = read_wal_lines(&wal::wal_path(&dir, id));
    assert!(base.len() >= 3, "multi-record baseline: {base:?}");
    // the meta record is v2 and embeds the canonical spec
    let meta = Json::parse(&base[0]).unwrap();
    let body = meta.get("body").unwrap();
    assert_eq!(body.get("version").and_then(Json::as_u64), Some(2));
    assert_eq!(body.get("spec").unwrap().to_string(), spec.canonical_string());

    for cut in 1..base.len() {
        let dir = case_dir(&format!("spec-v2-cut-{cut}"));
        write_wal(&wal::wal_path(&dir, id), &base[..cut], None);
        let s = stack();
        let f = factory(&s);
        let runner = SessionRunner::with_wal(1, TTL, &dir).unwrap();
        let empty: HashMap<String, Arc<dyn Protocol>> = HashMap::new();
        let report = runner.recover(&ds, &empty, Some(&f), None);
        assert_eq!(report.resumed, 1, "cut {cut}: must resume from the spec alone");
        let entry = runner.get(id).expect("recovered under its original id");
        assert_eq!(entry.wait_done(), SessionStatus::Done);
        assert_eq!(entry.rng_state(), rng_final, "cut {cut}: rng bit-identity");
        let lines = read_wal_lines(&wal::wal_path(&dir, id));
        assert_eq!(lines, base, "cut {cut}: recovered WAL must be byte-identical");
        runner.shutdown();
        s.batcher.stop();
    }
}

/// Without a factory, a v2 log falls back to the registry key — and a
/// registry miss leaves the log on disk as unusable, never truncated.
#[test]
fn v2_log_without_factory_or_registry_is_unusable_not_destroyed() {
    let spec = ProtocolSpec::minions("llama-3b", "gpt-4o");
    let ds = datasets();
    let sample = &ds.get("micro").unwrap().samples[1];
    let dir = case_dir("spec-v2-no-factory");
    let s = stack();
    let f = factory(&s);
    let proto = f.resolve(&spec).unwrap();
    let runner = SessionRunner::with_wal(1, TTL, &dir).unwrap();
    let entry = runner.spawn_durable(
        &proto,
        sample,
        Rng::seed_from(12),
        None,
        spec_meta(&spec, 1),
    );
    assert_eq!(entry.wait_done(), SessionStatus::Done);
    let id = entry.id;
    runner.shutdown();
    s.batcher.stop();
    // truncate to a non-terminal prefix, then "reboot" with neither a
    // factory nor a registry entry for the fingerprint key
    let base = read_wal_lines(&wal::wal_path(&dir, id));
    let dir2 = case_dir("spec-v2-no-factory-reboot");
    let path = wal::wal_path(&dir2, id);
    write_wal(&path, &base[..base.len() - 1], None);
    let runner = SessionRunner::with_wal(1, TTL, &dir2).unwrap();
    let empty: HashMap<String, Arc<dyn Protocol>> = HashMap::new();
    let report = runner.recover(&ds, &empty, None, None);
    assert_eq!(report.resumed, 0);
    assert_eq!(report.skipped_unusable, 1);
    assert!(path.exists(), "unusable logs stay on disk for post-mortem");
    runner.shutdown();
}

// ---------------------------------------------------------------------
// The checked-in v1 fixture.
// ---------------------------------------------------------------------

/// The deterministic stub the fixture's `proto_key` ("fixture") resolves
/// to: one rng draw decides the ledger, so the WAL a recovery writes is
/// a function of the recovered rng checkpoint — a real bit-identity
/// probe, not a constant.
struct FixtureProto;

impl Protocol for FixtureProto {
    fn name(&self) -> String {
        "fixture".into()
    }

    fn session(&self, sample: &Sample) -> Box<dyn ProtocolSession> {
        let truth = sample.query.answer.clone();
        OneShotSession::boxed(move |rng: &mut Rng| -> Result<Outcome> {
            let mut ledger = Ledger::default();
            ledger.remote_msg(rng.next_u64() % 100 + 1, 10);
            Ok(Outcome {
                answer: truth.clone(),
                ledger,
                rounds: 1,
                transcript: vec![],
            })
        })
    }
}

const FIXTURE_ID: u64 = 901;

fn install_fixture(dir: &Path) -> &'static str {
    let fixture = include_str!("fixtures/session-901.wal");
    std::fs::write(wal::wal_path(dir, FIXTURE_ID), fixture).expect("install fixture");
    fixture
}

#[test]
fn checked_in_v1_fixture_recovers_byte_identically_alongside_v2() {
    // build a non-terminal v2 log (meta + first step) to sit alongside
    let spec = ProtocolSpec::minions("llama-3b", "gpt-4o");
    let ds = datasets();
    let sample = &ds.get("micro").unwrap().samples[0];
    let prep = case_dir("v1-fixture-prep");
    let s = stack();
    let f = factory(&s);
    let proto = f.resolve(&spec).unwrap();
    let runner = SessionRunner::with_wal(1, TTL, &prep).unwrap();
    let entry = runner.spawn_durable(
        &proto,
        sample,
        Rng::seed_from(11),
        None,
        spec_meta(&spec, 0),
    );
    assert_eq!(entry.wait_done(), SessionStatus::Done);
    let v2_id = entry.id;
    runner.shutdown();
    s.batcher.stop();
    let v2_lines = read_wal_lines(&wal::wal_path(&prep, v2_id));

    // one state dir, both generations: the fixture v1 log + a v2 prefix
    let run = |case: &str| -> (Vec<String>, Vec<String>) {
        let dir = case_dir(case);
        let fixture = install_fixture(&dir);
        write_wal(&wal::wal_path(&dir, v2_id), &v2_lines[..2], None);
        let s = stack();
        let f = factory(&s);
        let runner = SessionRunner::with_wal(1, TTL, &dir).unwrap();
        let mut protos: HashMap<String, Arc<dyn Protocol>> = HashMap::new();
        protos.insert("fixture".to_string(), Arc::new(FixtureProto));
        let report = runner.recover(&ds, &protos, Some(&f), None);
        assert_eq!(report.resumed, 2, "v1 and v2 logs must both resume");
        let v1 = runner.get(FIXTURE_ID).expect("fixture session registered");
        assert_eq!(v1.wait_done(), SessionStatus::Done);
        let v2 = runner.get(v2_id).expect("v2 session registered");
        assert_eq!(v2.wait_done(), SessionStatus::Done);
        runner.shutdown();
        s.batcher.stop();
        let v1_lines = read_wal_lines(&wal::wal_path(&dir, FIXTURE_ID));
        // the checked-in meta record is preserved byte for byte
        assert_eq!(format!("{}\n", v1_lines[0]), fixture);
        assert!(v1_lines.len() >= 2, "completion appended records");
        (v1_lines, read_wal_lines(&wal::wal_path(&dir, v2_id)))
    };

    // recovering the same fixture twice is byte-identical — the v1
    // replay path is as deterministic as the spec path
    let (a1, a2) = run("v1-fixture-a");
    let (b1, b2) = run("v1-fixture-b");
    assert_eq!(a1, b1, "v1 fixture recovery must be byte-identical");
    assert_eq!(a2, b2, "v2 recovery must be byte-identical");
    assert_eq!(a2, v2_lines, "v2 prefix converges to the uninterrupted run");
}
