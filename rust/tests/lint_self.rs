//! Self-test for `minions lint` (DESIGN.md §10).
//!
//! Two subjects, one pass each way:
//!
//! - the **fixture corpus** (`rust/tests/fixtures/lint/corpus/`) carries
//!   one known violation per rule plus pragma'd exceptions, and the
//!   diagnostics must match the golden `expected.txt` byte-for-byte —
//!   so a rule that stops firing (or starts over-firing) breaks here
//!   before it silently stops protecting the tree;
//! - the **real tree** must lint clean, and its fresh panic-site counts
//!   must equal the checked-in `LINT_BASELINE.json` exactly — so an
//!   improvement cannot merge without ratcheting the baseline down.

use minions::lint;
use std::path::{Path, PathBuf};
use std::process::Command;

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn corpus_root() -> PathBuf {
    repo_root().join("rust/tests/fixtures/lint/corpus")
}

#[test]
fn corpus_matches_golden_diagnostics() {
    let outcome = lint::run(&corpus_root()).expect("lint over corpus");
    let got: Vec<String> = outcome.diags.iter().map(|d| d.to_string()).collect();
    let golden_path = repo_root().join("rust/tests/fixtures/lint/expected.txt");
    let golden = std::fs::read_to_string(&golden_path).expect("read golden diagnostics");
    let want: Vec<String> = golden.lines().map(str::to_string).collect();
    assert_eq!(
        got, want,
        "corpus diagnostics drifted from {}",
        golden_path.display()
    );
}

#[test]
fn corpus_covers_every_rule_and_respects_pragmas() {
    let outcome = lint::run(&corpus_root()).expect("lint over corpus");
    for rule in [
        lint::rules::RULE_DETERMINISM,
        lint::rules::RULE_CONSTRUCTION,
        lint::rules::RULE_TAXONOMY,
        lint::rules::RULE_LOCKS,
    ] {
        assert!(
            outcome.diags.iter().any(|d| d.rule == rule),
            "corpus has no {rule} diagnostic"
        );
    }
    // the pragma'd HashSet in the corpus wal.rs must not diagnose
    assert!(
        !outcome.diags.iter().any(|d| d.msg.contains("HashSet")),
        "pragma'd HashSet line diagnosed anyway"
    );
    // rule 5: 2 unwraps + 1 index expr; the pragma'd expect is excluded
    let counts: Vec<(&str, usize)> = outcome
        .counts
        .iter()
        .map(|(k, v)| (k.as_str(), *v))
        .collect();
    assert_eq!(counts, vec![("rust/src/sched/mod.rs", 3)]);
    // no baseline is checked into the corpus: that is itself a failure
    assert_eq!(outcome.ratchet.len(), 1);
    assert!(!outcome.clean());
}

#[test]
fn real_tree_is_clean_and_baseline_is_fresh() {
    let root = repo_root();
    let outcome = lint::run(&root).expect("lint over the real tree");
    assert!(
        outcome.diags.is_empty(),
        "rule violations in the tree:\n{}",
        outcome.render_text()
    );
    let baseline = lint::baseline::load(&root)
        .expect("read LINT_BASELINE.json")
        .expect("LINT_BASELINE.json must be checked in");
    // equality, not <=: a stale (too-high) baseline must not merge, so
    // every improvement is forced through `lint --write-baseline`
    assert_eq!(
        outcome.counts, baseline.counts,
        "LINT_BASELINE.json is stale — run `minions lint --write-baseline`"
    );
    assert!(outcome.ratchet.is_empty(), "{:?}", outcome.ratchet);
    assert!(outcome.improved.is_empty(), "{:?}", outcome.improved);
    assert!(outcome.clean());
}

fn run_lint(root: &Path) -> (i32, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_minions"))
        .args(["lint", "--ci", "--root"])
        .arg(root)
        .output()
        .expect("spawn minions lint");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).into_owned(),
    )
}

#[test]
fn cli_exit_codes_gate_ci() {
    let (code, stdout) = run_lint(&corpus_root());
    assert_eq!(code, 1, "corpus must fail the gate; stdout:\n{stdout}");
    for rule in ["determinism", "construction-path", "error-taxonomy", "lock-discipline"] {
        assert!(stdout.contains(rule), "missing {rule} in:\n{stdout}");
    }
    let (code, stdout) = run_lint(&repo_root());
    assert_eq!(code, 0, "the real tree must pass the gate; stdout:\n{stdout}");
}

#[test]
fn report_json_round_trips() {
    let outcome = lint::run(&corpus_root()).expect("lint over corpus");
    let report = format!("{}", outcome.report_json());
    let parsed = minions::util::json::Json::parse(&report).expect("report parses");
    let violations = parsed
        .get("violations")
        .and_then(|v| v.as_arr())
        .expect("violations array");
    assert_eq!(violations.len(), outcome.diags.len());
    let total = parsed
        .get("panic_free")
        .and_then(|p| p.get("total"))
        .and_then(|t| t.as_u64())
        .expect("panic_free.total");
    assert_eq!(total as usize, outcome.total_panic_sites());
}
