//! Integration: the AOT bridge end-to-end.
//!
//! Loads the HLO-text artifacts, compiles them on the PJRT CPU client, and
//! asserts the outputs match the pure-Rust native oracle (same weights,
//! same math, two implementations) and that planted facts are recovered.
//!
//! Requires `make artifacts` to have run (the Makefile test target does).

use minions::runtime::{
    default_artifact_dir, EmbedRequest, Engine, Manifest, NativeBackend, ScoreRequest,
};
use minions::util::rng::Rng;
use minions::vocab::{BATCH, CHUNK, FACT_SLOT, KEY_LEN, QLEN, VAL_BASE, VAL_END};

fn artifacts_available() -> bool {
    default_artifact_dir().join("manifest.json").exists()
}

fn manifest() -> Manifest {
    Manifest::load(default_artifact_dir()).expect("manifest loads")
}

/// Build a batched request with one planted fact per row.
fn planted_request(d: usize, seed: u64) -> (ScoreRequest, Vec<usize>, Vec<u32>) {
    let mut rng = Rng::seed_from(seed);
    let mut q_tokens = vec![0i32; BATCH * QLEN];
    let mut q_weights = vec![0f32; BATCH * QLEN];
    let mut c_tokens = vec![0i32; BATCH * CHUNK];
    let c_mask = vec![1f32; BATCH * CHUNK];

    // wpos from the weight file (the same weights the module will use)
    let m = manifest();
    let spec = m.score_module(d).unwrap();
    let wf = minions::runtime::WeightFile::load(&spec.weights).unwrap();
    let wpos = &wf.get("wpos").unwrap().data;

    let mut positions = Vec::new();
    let mut values = Vec::new();
    for b in 0..BATCH {
        let key: Vec<u32> = (0..KEY_LEN)
            .map(|_| rng.range(16, 4096) as u32)
            .collect();
        let val = rng.range(VAL_BASE as usize, VAL_END as usize) as u32;
        // filler
        for c in 0..CHUNK {
            c_tokens[b * CHUNK + c] = rng.range(VAL_BASE as usize, VAL_END as usize) as i32;
        }
        let slot = rng.range(0, CHUNK / FACT_SLOT - 1);
        let pos = slot * FACT_SLOT;
        for (i, k) in key.iter().enumerate() {
            c_tokens[b * CHUNK + pos + i] = *k as i32;
        }
        c_tokens[b * CHUNK + pos + KEY_LEN] = val as i32;
        for (i, k) in key.iter().enumerate() {
            q_tokens[b * QLEN + i] = *k as i32;
            q_weights[b * QLEN + i] = wpos[i];
        }
        positions.push(pos);
        values.push(val);
    }
    (
        ScoreRequest {
            d,
            q_tokens,
            q_weights,
            c_tokens,
            c_mask,
        },
        positions,
        values,
    )
}

#[test]
fn pjrt_matches_native_oracle_and_recovers_facts() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::start(manifest(), &[]).expect("engine starts");
    let native = NativeBackend::new(manifest()).unwrap();

    for d in [64usize, 128] {
        let (req, positions, _vals) = planted_request(d, 42 + d as u64);
        let got = engine.score(req.clone()).expect("pjrt score");
        let want = native.score(&req).expect("native score");

        assert_eq!(got.scores.len(), BATCH * CHUNK);
        let mut max_err = 0f32;
        for (g, w) in got.scores.iter().zip(&want.scores) {
            // NEG_INF entries compare exactly; others to float tolerance
            if *w < -1e29 {
                assert!(*g < -1e29);
            } else {
                max_err = max_err.max((g - w).abs());
            }
        }
        assert!(max_err < 1e-4, "d={d} score divergence {max_err}");
        for (g, w) in got.lse.iter().zip(&want.lse) {
            assert!((g - w).abs() < 1e-3, "lse divergence {g} vs {w}");
        }

        // argmax recovers the planted fact (no distractors here)
        for b in 0..BATCH {
            let row = &got.scores[b * CHUNK..(b + 1) * CHUNK];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(argmax, positions[b], "d={d} row {b}");
        }
    }
}

#[test]
fn pjrt_embed_matches_native() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::start(manifest(), &[]).expect("engine starts");
    let native = NativeBackend::new(manifest()).unwrap();
    let mut rng = Rng::seed_from(7);
    let c_tokens: Vec<i32> = (0..BATCH * CHUNK)
        .map(|_| rng.range(16, 8192) as i32)
        .collect();
    let mut c_mask = vec![1f32; BATCH * CHUNK];
    // one row half-masked
    for c in CHUNK / 2..CHUNK {
        c_mask[3 * CHUNK + c] = 0.0;
    }
    let req = EmbedRequest { c_tokens, c_mask };
    let got = engine.embed(req.clone()).unwrap();
    let want = native.embed(&req).unwrap();
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 1e-5, "{g} vs {w}");
    }
}

#[test]
fn engine_stats_accumulate() {
    if !artifacts_available() {
        return;
    }
    let engine = Engine::start(manifest(), &[]).unwrap();
    let (req, _, _) = planted_request(64, 1);
    engine.score(req.clone()).unwrap();
    engine.score(req).unwrap();
    let stats = engine.stats();
    assert_eq!(stats.dispatches, 2);
    assert_eq!(stats.rows, 2 * BATCH as u64);
    assert!(stats.exec_secs > 0.0);
}
