//! Fixture: rule 2 (construction-path) violation — a protocol built
//! directly instead of through `ProtocolFactory::resolve`.

pub fn build(local: Lm, remote: Lm, cfg: Config) -> MinionS {
    MinionS::new(local, remote, cfg)
}
