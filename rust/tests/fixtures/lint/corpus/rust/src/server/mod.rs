//! Fixture: rule 3 (error-taxonomy) violation — saturation detected by
//! string-matching the rendered message instead of `sched::is_saturated`.

pub fn is_busy(e: &anyhow::Error) -> bool {
    e.to_string().contains("scheduler saturated")
}
