//! Fixture: rule 1 (determinism) violations in a serialization path.
//! This file never compiles — it exists to trip the lint on purpose.

use std::collections::HashMap;
use std::time::SystemTime;

pub fn write_record(n: u64) -> String {
    let t = SystemTime::now();
    format!("{t:?} {n}")
}

pub fn render_cost(x: f64) -> String {
    format!("{:.4}", x)
}

// lint: allow(determinism, "fixture: a justified exception that must not diagnose")
pub type AllowedSet = std::collections::HashSet<u32>;

#[cfg(test)]
mod tests {
    use std::collections::HashMap as TestMap; // test region: excluded
}
