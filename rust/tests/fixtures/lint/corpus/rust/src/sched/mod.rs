//! Fixture: rule 4 (lock-discipline) violation — a guard held across a
//! channel send — plus rule 5 (panic-free) sites for the count test.

pub fn drain(&self) {
    let mut st = self.state.lock().unwrap();
    st.tick += 1;
    self.tx.send(st.tick).unwrap();
}

pub fn peek(xs: &[u32]) -> u32 {
    xs[0]
}

pub fn boot() {
    // lint: allow(panic-free, "fixture: a justified panic site, excluded from the count")
    spawn().expect("boot");
}
