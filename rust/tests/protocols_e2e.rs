//! End-to-end protocol integration on the native backend: the full
//! decompose→execute→aggregate loop over generated datasets, asserting
//! the paper's *ordering* properties (remote-only ≥ minions ≥ minion ≥
//! local-only on accuracy; reversed on remote cost). All scoring flows
//! through a shared `DynamicBatcher`, exactly as in the real stack.

use minions::data;
use minions::eval::{run_protocol, run_protocol_parallel};
use minions::model::{local, remote, LocalLm, RemoteLm};
use minions::protocol::{LocalOnly, Minion, MinionS, MinionsConfig, Protocol, RemoteOnly};
use minions::runtime::{default_artifact_dir, Backend, Manifest, NativeBackend};
use minions::sched::{DynamicBatcher, DEFAULT_MAX_WAIT};
use std::sync::Arc;

fn setup() -> Option<(Arc<DynamicBatcher>, Manifest)> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend::new(manifest.clone()).unwrap());
    Some((DynamicBatcher::new(backend, DEFAULT_MAX_WAIT), manifest))
}

#[test]
fn minions_beats_local_and_costs_less_than_remote() {
    let Some((batcher, manifest)) = setup() else {
        return;
    };
    let local = Arc::new(LocalLm::new(batcher.clone(), &manifest, local::LLAMA_8B).unwrap());
    let remote = Arc::new(RemoteLm::new(batcher.clone(), &manifest, remote::GPT_4O).unwrap());

    let ds = data::generate("finance", 12, 99);
    let r_remote = run_protocol(&RemoteOnly::new(remote.clone()), &ds, 1, true).unwrap();
    let r_local = run_protocol(&LocalOnly::new(local.clone()), &ds, 1, true).unwrap();
    let r_minions = run_protocol(
        &MinionS::new(local.clone(), remote.clone(), MinionsConfig::default()),
        &ds,
        1,
        true,
    )
    .unwrap();

    eprintln!(
        "remote={:.2}/${:.4} local={:.2} minions={:.2}/${:.4}",
        r_remote.accuracy,
        r_remote.mean_usd(),
        r_local.accuracy,
        r_minions.accuracy,
        r_minions.mean_usd()
    );
    // ordering properties (the paper's headline shape)
    assert!(r_remote.accuracy >= r_minions.accuracy - 0.15);
    assert!(r_minions.accuracy > r_local.accuracy + 0.1);
    assert!(r_minions.mean_usd() < 0.5 * r_remote.mean_usd());
    assert!(r_local.mean_usd() == 0.0);
}

#[test]
fn minion_chat_is_cheapest_but_weaker_than_minions() {
    let Some((batcher, manifest)) = setup() else {
        return;
    };
    let local = Arc::new(LocalLm::new(batcher.clone(), &manifest, local::LLAMA_8B).unwrap());
    let remote = Arc::new(RemoteLm::new(batcher.clone(), &manifest, remote::GPT_4O).unwrap());

    let ds = data::generate("health", 12, 7);
    let r_minion =
        run_protocol(&Minion::new(local.clone(), remote.clone(), 3), &ds, 2, true).unwrap();
    let r_minions = run_protocol(
        &MinionS::new(local.clone(), remote.clone(), MinionsConfig::default()),
        &ds,
        2,
        true,
    )
    .unwrap();
    eprintln!(
        "minion={:.2}/${:.5} minions={:.2}/${:.5}",
        r_minion.accuracy,
        r_minion.mean_usd(),
        r_minions.accuracy,
        r_minions.mean_usd()
    );
    assert!(r_minion.mean_usd() < r_minions.mean_usd());
    assert!(r_minions.accuracy >= r_minion.accuracy);
}

#[test]
fn capacity_ladder_orders_accuracy() {
    let Some((batcher, manifest)) = setup() else {
        return;
    };
    let remote = Arc::new(RemoteLm::new(batcher.clone(), &manifest, remote::GPT_4O).unwrap());
    let ds = data::generate("qasper", 12, 3);
    let mut accs = Vec::new();
    for profile in [local::LLAMA_1B, local::LLAMA_3B, local::LLAMA_8B] {
        let local = Arc::new(LocalLm::new(batcher.clone(), &manifest, profile).unwrap());
        let r = run_protocol(
            &MinionS::new(local, remote.clone(), MinionsConfig::default()),
            &ds,
            4,
            true,
        )
        .unwrap();
        eprintln!("{}: acc={:.2}", profile.name, r.accuracy);
        accs.push(r.accuracy);
    }
    // monotone within slack (small n)
    assert!(accs[2] >= accs[0] - 0.05, "8B {} vs 1B {}", accs[2], accs[0]);
    assert!(accs[2] > 0.4, "8B should be decent: {}", accs[2]);
}

#[test]
fn parallel_eval_is_bit_identical_on_real_weights() {
    let Some((batcher, manifest)) = setup() else {
        return;
    };
    let local = Arc::new(LocalLm::new(batcher.clone(), &manifest, local::LLAMA_8B).unwrap());
    let remote = Arc::new(RemoteLm::new(batcher.clone(), &manifest, remote::GPT_4O).unwrap());
    let proto: Arc<dyn Protocol> =
        Arc::new(MinionS::new(local, remote, MinionsConfig::default()));
    let ds = data::generate("finance", 10, 17);

    let serial = run_protocol(proto.as_ref(), &ds, 17, true).unwrap();
    for threads in [2usize, 4, 8] {
        let par = run_protocol_parallel(Arc::clone(&proto), &ds, 17, true, threads).unwrap();
        assert_eq!(serial.scores, par.scores, "{threads} threads");
        assert_eq!(serial.accuracy.to_bits(), par.accuracy.to_bits());
        assert_eq!(serial.cost.total, par.cost.total);
        assert_eq!(serial.mean_rounds, par.mean_rounds);
        for (a, b) in serial.outcomes.iter().zip(&par.outcomes) {
            assert_eq!(a.answer, b.answer);
            assert_eq!(a.rounds, b.rounds);
        }
    }
}
