//! Integration: the engine worker pool (DESIGN.md §11).
//!
//! Runs entirely against synthetic artifacts (`runtime::synth`), so it
//! needs neither `make artifacts` nor the `xla-pjrt` feature. Asserts:
//!
//! - bit-identical responses at 1, 4, and 8 workers (and vs the native
//!   oracle) — parallel dispatch reorders work, never results;
//! - the shared stats counters (dispatches, rows, workers, pooled-query
//!   memo hits/misses) account for every request exactly once;
//! - malformed requests are rejected at the handle with the shared
//!   validation message, and the pool keeps serving afterwards.

#![cfg(not(feature = "xla-pjrt"))]

use minions::runtime::synth::write_synthetic_artifacts;
use minions::runtime::{EmbedRequest, Engine, Manifest, NativeBackend, ScoreRequest};
use minions::util::rng::Rng;
use minions::vocab::{BATCH, CHUNK, QLEN, VOCAB};

fn synth_manifest(tag: &str) -> (Manifest, std::path::PathBuf) {
    let dir = std::env::temp_dir()
        .join(format!("minions-engine-pool-{tag}-{}", std::process::id()));
    let m = write_synthetic_artifacts(&dir, &[64], 64, 11).expect("synthetic artifacts");
    (m, dir)
}

fn rand_request(rng: &mut Rng) -> ScoreRequest {
    ScoreRequest {
        d: 64,
        q_tokens: (0..BATCH * QLEN).map(|_| rng.below(VOCAB) as i32).collect(),
        q_weights: (0..BATCH * QLEN)
            .map(|_| if rng.bool(0.2) { 0.0 } else { rng.f32() })
            .collect(),
        c_tokens: (0..BATCH * CHUNK).map(|_| rng.below(VOCAB) as i32).collect(),
        c_mask: (0..BATCH * CHUNK)
            .map(|_| if rng.bool(0.25) { 0.0 } else { 1.0 })
            .collect(),
    }
}

#[test]
fn pool_results_bit_identical_across_worker_counts() {
    let (manifest, dir) = synth_manifest("det");
    let native = NativeBackend::new(manifest.clone()).expect("native oracle");
    let mut rng = Rng::seed_from(5);
    let reqs: Vec<ScoreRequest> = (0..12).map(|_| rand_request(&mut rng)).collect();
    let oracle: Vec<_> = reqs.iter().map(|r| native.score(r).expect("oracle")).collect();

    for workers in [1usize, 4, 8] {
        let engine = Engine::start_pool(manifest.clone(), &[64], workers).expect("pool");
        assert_eq!(engine.workers(), workers);
        // concurrent clients: one per request, all in flight at once
        let responses: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = reqs
                .iter()
                .map(|r| {
                    let eng = engine.clone();
                    let req = r.clone();
                    s.spawn(move || eng.score(req).expect("score"))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("join")).collect()
        });
        for (i, (got, want)) in responses.iter().zip(&oracle).enumerate() {
            let got_bits: Vec<u32> = got.scores.iter().map(|v| v.to_bits()).collect();
            let want_bits: Vec<u32> = want.scores.iter().map(|v| v.to_bits()).collect();
            assert_eq!(got_bits, want_bits, "scores diverge at {workers} workers, req {i}");
            let got_lse: Vec<u32> = got.lse.iter().map(|v| v.to_bits()).collect();
            let want_lse: Vec<u32> = want.lse.iter().map(|v| v.to_bits()).collect();
            assert_eq!(got_lse, want_lse, "lse diverges at {workers} workers, req {i}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stats_account_for_every_dispatch_and_memo_hit() {
    let (manifest, dir) = synth_manifest("stats");
    let engine = Engine::start_pool(manifest, &[64], 1).expect("pool");
    let mut rng = Rng::seed_from(9);
    // one shared query template across all rows and requests: after the
    // single cold miss, every pooled-query lookup on the one worker hits
    let qt: Vec<i32> = (0..QLEN).map(|_| rng.below(VOCAB) as i32).collect();
    let qw: Vec<f32> = (0..QLEN).map(|_| rng.f32() * 0.5 + 0.1).collect();
    let n_reqs = 6;
    for _ in 0..n_reqs {
        let mut q_tokens = Vec::with_capacity(BATCH * QLEN);
        let mut q_weights = Vec::with_capacity(BATCH * QLEN);
        for _ in 0..BATCH {
            q_tokens.extend_from_slice(&qt);
            q_weights.extend_from_slice(&qw);
        }
        let req = ScoreRequest {
            d: 64,
            q_tokens,
            q_weights,
            c_tokens: (0..BATCH * CHUNK).map(|_| rng.below(VOCAB) as i32).collect(),
            c_mask: vec![1.0; BATCH * CHUNK],
        };
        engine.score(req).expect("score");
    }
    let st = engine.stats();
    assert_eq!(st.dispatches, n_reqs as u64);
    assert_eq!(st.rows, (n_reqs * BATCH) as u64);
    assert_eq!(st.workers, 1);
    assert_eq!(st.pooled_q_misses, 1, "one cold template");
    assert_eq!(st.pooled_q_hits, (n_reqs * BATCH - 1) as u64);
    assert!(st.exec_secs > 0.0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn malformed_requests_rejected_and_pool_survives() {
    let (manifest, dir) = synth_manifest("reject");
    let engine = Engine::start_pool(manifest, &[64], 2).expect("pool");

    // wrong q_tokens length: caught by the shared handle-side validation
    let bad_shape = ScoreRequest {
        d: 64,
        q_tokens: vec![1; QLEN], // one row, not BATCH
        q_weights: vec![0.5; BATCH * QLEN],
        c_tokens: vec![1; BATCH * CHUNK],
        c_mask: vec![1.0; BATCH * CHUNK],
    };
    let err = engine.score(bad_shape).expect_err("shape mismatch must fail");
    assert!(err.to_string().contains("shape mismatch"), "got: {err}");

    // out-of-vocab token id: caught before any embedding lookup
    let mut bad_token = ScoreRequest {
        d: 64,
        q_tokens: vec![1; BATCH * QLEN],
        q_weights: vec![0.5; BATCH * QLEN],
        c_tokens: vec![1; BATCH * CHUNK],
        c_mask: vec![1.0; BATCH * CHUNK],
    };
    bad_token.c_tokens[3] = VOCAB as i32;
    let err = engine.score(bad_token.clone()).expect_err("token range must fail");
    assert!(err.to_string().contains("outside vocab"), "got: {err}");

    // malformed embed: same shared validation path
    let err = engine
        .embed(EmbedRequest {
            c_tokens: vec![1; CHUNK],
            c_mask: vec![1.0; BATCH * CHUNK],
        })
        .expect_err("embed shape mismatch must fail");
    assert!(err.to_string().contains("shape mismatch"), "got: {err}");

    // the pool is still healthy: a valid request round-trips
    bad_token.c_tokens[3] = 1;
    let resp = engine.score(bad_token).expect("valid request after rejects");
    assert_eq!(resp.scores.len(), BATCH * CHUNK);
    let st = engine.stats();
    assert_eq!(st.dispatches, 1, "rejected requests never reach a worker");
    std::fs::remove_dir_all(&dir).ok();
}
