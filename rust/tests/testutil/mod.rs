//! Shared fixtures for the durability fault-injection suite
//! (`tests/durability.rs`): the deterministic pseudo backend + protocol
//! stack the parity tests already standardize on, a forced-two-round
//! MinionS remote (so the kill-and-recover sweep always exercises a
//! multi-round WAL), and the WAL corpus helpers (corpus root, torn-write
//! prefixes). Artifact-free: everything runs in every environment.
//!
//! Corpus layout: each test case writes under `corpus_root()/<case>`;
//! the CI `durability` job points `MINIONS_DURABILITY_DIR` at a tmpfs
//! and uploads the whole corpus as an artifact when the suite fails, so
//! a red run ships its WALs for post-mortem.

#![allow(dead_code)]

use anyhow::Result;
use minions::data::{self, Answer, Dataset, Query};
use minions::dsl;
use minions::model::job::WorkerOutput;
use minions::model::{local, remote, Decision, LocalLm, MinionsRemote, PlanConfig, RemoteLm};
use minions::protocol::{
    LocalOnly, Minion, MinionS, MinionsConfig, Protocol, ProtocolFactory, ProtocolSpec, RemoteOnly,
};
use minions::rag::{Rag, Retriever};
use minions::runtime::{Backend, EmbedRequest, Manifest, ScoreRequest, ScoreResponse};
use minions::sched::DynamicBatcher;
use minions::server::wal::{self, segment};
use minions::util::json::Json;
use minions::util::rng::{mix64, Rng};
use minions::vocab::{BATCH, CHUNK, QLEN};
use std::collections::HashMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

/// Deterministic, content-sensitive, row-independent scorer (the same
/// construction `tests/cache_parity.rs` and `tests/parallel_eval.rs`
/// use). Purely functional: two processes given identical rows compute
/// identical scores, which is what makes kill-and-recover bit-identity
/// assertable at all.
pub struct PseudoBackend;

impl Backend for PseudoBackend {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse> {
        let mut scores = vec![-1.0e30f32; BATCH * CHUNK];
        let mut lse = vec![0f32; BATCH];
        for b in 0..BATCH {
            let q0 = req.q_tokens[b * QLEN] as u64;
            let q1 = req.q_tokens[b * QLEN + 1] as u64;
            for c in 0..CHUNK {
                if req.c_mask[b * CHUNK + c] == 0.0 {
                    continue;
                }
                let t = req.c_tokens[b * CHUNK + c] as u64;
                let h = mix64(
                    q0 ^ (q1 << 16) ^ (t << 32) ^ ((c as u64) << 48) ^ ((req.d as u64) << 60),
                );
                scores[b * CHUNK + c] = ((h >> 11) as f64 / (1u64 << 53) as f64 * 1.5) as f32;
            }
            lse[b] = 1.0;
        }
        Ok(ScoreResponse { scores, lse })
    }

    fn embed(&self, _req: EmbedRequest) -> Result<Vec<f32>> {
        unimplemented!("the durability suite runs the lexical retriever")
    }

    fn name(&self) -> &'static str {
        "pseudo"
    }
}

/// A MinionS remote that always runs exactly two rounds: `MoreRounds`
/// after round 1, a deterministic `Final` after round 2 — so the
/// recovery sweep always sees the full multi-round record sequence
/// (meta, planned, round_executed, planned, finalized) regardless of
/// what the data would make the real remote decide. It consumes one rng
/// draw per synthesis, making the WAL's rng checkpoints load-bearing.
pub struct ForcedTwoRounds;

impl MinionsRemote for ForcedTwoRounds {
    fn label(&self) -> String {
        "forced-2r".into()
    }

    fn plan_minions(
        &self,
        query: &Query,
        cfg: &PlanConfig,
        _round: usize,
        _advice: &str,
        _had_answers: bool,
    ) -> String {
        let task = format!("EXTRACT {}", dsl::render_task_key(&query.keys[0]));
        format!(
            "tasks = [\"{task}\"]\n\
             for task_id, task in enumerate(tasks):\n    \
             for doc_id, document in enumerate(context):\n        \
             chunks = chunk_on_multiple_pages(document, {})\n        \
             for chunk_id, chunk in enumerate(chunks):\n            \
             job_manifests.append(JobManifest(task_id=task_id, chunk=chunk, task=task, advice=\"\"))\n",
            cfg.pages_per_chunk
        )
    }

    fn synthesize(
        &self,
        _query: &Query,
        outputs: &[WorkerOutput],
        round: usize,
        _max_rounds: usize,
        rng: &mut Rng,
    ) -> Result<Decision> {
        // a deterministic draw: recovery must resume the stream exactly
        // here for the final answer to come out bit-identical
        let _ = rng.next_u64();
        if round < 2 {
            return Ok(Decision::MoreRounds {
                advice: "one more round".into(),
            });
        }
        let best = outputs
            .iter()
            .filter(|o| o.answer.is_some())
            .max_by(|a, b| a.confidence.partial_cmp(&b.confidence).unwrap())
            .and_then(|o| o.answer)
            .unwrap_or(0);
        Ok(Decision::Final(Answer::Value(best)))
    }
}

/// Reusable open-once latch for deterministic scheduling in tests: a
/// session step parks on `wait()` until the test calls `open()`.
#[derive(Clone, Default)]
pub struct Gate {
    state: Arc<(std::sync::Mutex<bool>, std::sync::Condvar)>,
}

impl Gate {
    pub fn open(&self) {
        let (lock, cv) = &*self.state;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }

    pub fn wait(&self) {
        let (lock, cv) = &*self.state;
        let mut open = lock.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
    }
}

pub struct Stack {
    pub batcher: Arc<DynamicBatcher>,
    pub local: Arc<LocalLm>,
    pub remote: Arc<RemoteLm>,
}

/// The stub manifest every artifact-free stack/factory shares.
pub fn stub_manifest() -> Manifest {
    Manifest::stub_for_tests(&[64, 128, 256, 1024], vec![1.0, 0.5, 0.25])
}

/// A fresh scoring stack — built per "process" so recovery runs against
/// a cold batcher/cache exactly like a restarted server would.
pub fn stack() -> Stack {
    let batcher = DynamicBatcher::new(Arc::new(PseudoBackend), Duration::from_millis(2));
    let manifest = stub_manifest();
    let local = Arc::new(
        LocalLm::with_cache(Arc::clone(&batcher), &manifest, local::LLAMA_3B, None).unwrap(),
    );
    let remote = Arc::new(
        RemoteLm::with_cache(Arc::clone(&batcher), &manifest, remote::GPT_4O, None).unwrap(),
    );
    Stack {
        batcher,
        local,
        remote,
    }
}

/// A `ProtocolFactory` over the stack's batcher and the stub manifest —
/// what a spec-serving server (or WAL v2 recovery) would resolve specs
/// through in these artifact-free tests. Cache off, matching `stack()`,
/// so factory-built and stack-built protocols are bit-identical.
pub fn factory(s: &Stack) -> Arc<ProtocolFactory> {
    Arc::new(ProtocolFactory::new(
        Arc::new(PseudoBackend),
        Arc::clone(&s.batcher),
        stub_manifest(),
        None,
    ))
}

/// The spec equivalent of each spec-expressible [`protocols`] registry
/// entry, for the durability suite's WAL-v2 mode. `minions-2r` (custom
/// forced-two-round remote) and ad-hoc test stubs have no spec — they
/// stay on v1 meta records, keeping the registry replay path exercised.
pub fn spec_for(proto_key: &str) -> Option<ProtocolSpec> {
    match proto_key {
        "local" => Some(ProtocolSpec::local_only("llama-3b")),
        "remote" => Some(ProtocolSpec::remote_only("gpt-4o")),
        "minion" => Some(ProtocolSpec::minion("llama-3b", "gpt-4o", 3)),
        "minions" => Some(ProtocolSpec::minions("llama-3b", "gpt-4o")),
        "rag" => Some(ProtocolSpec::rag(Retriever::Bm25, "gpt-4o", 4)),
        _ => None,
    }
}

/// `MINIONS_WAL_META=v2` flips the durability suite to spec-bearing v2
/// meta records for every spec-expressible protocol (the CI matrix runs
/// both modes); anything else means v1.
pub fn v2_meta_mode() -> bool {
    std::env::var("MINIONS_WAL_META").map(|v| v == "v2").unwrap_or(false)
}

/// Every protocol family keyed the way a server registry would key them;
/// `minions-2r` is the forced-two-round variant the multi-round sweep
/// relies on.
pub fn protocols(s: &Stack) -> HashMap<String, Arc<dyn Protocol>> {
    let mut map: HashMap<String, Arc<dyn Protocol>> = HashMap::new();
    map.insert(
        "local".into(),
        Arc::new(LocalOnly::new(Arc::clone(&s.local))),
    );
    map.insert(
        "remote".into(),
        Arc::new(RemoteOnly::new(Arc::clone(&s.remote))),
    );
    map.insert(
        "minion".into(),
        Arc::new(Minion::new(Arc::clone(&s.local), Arc::clone(&s.remote), 3)),
    );
    map.insert(
        "minions".into(),
        Arc::new(MinionS::new(
            Arc::clone(&s.local),
            Arc::clone(&s.remote),
            MinionsConfig::default(),
        )),
    );
    map.insert(
        "minions-2r".into(),
        Arc::new(MinionS::new(
            Arc::clone(&s.local),
            Arc::new(ForcedTwoRounds),
            MinionsConfig {
                max_rounds: 3,
                ..MinionsConfig::default()
            },
        )),
    );
    map.insert(
        "rag".into(),
        Arc::new(Rag::new(
            Arc::clone(&s.remote),
            Arc::new(PseudoBackend),
            Retriever::Bm25,
            4,
        )),
    );
    map
}

/// The dataset registry recovery resolves sessions against. Multi-part
/// queries so the chat protocol runs several rounds.
pub fn datasets() -> HashMap<String, Dataset> {
    let mut map = HashMap::new();
    map.insert("micro".to_string(), data::micro::multistep_sweep(2, 3, 5));
    map
}

// ---------------------------------------------------------------------
// WAL corpus helpers.
// ---------------------------------------------------------------------

/// Corpus root: `MINIONS_DURABILITY_DIR` when set (CI points it at a
/// tmpfs and uploads it on failure), else a per-process temp dir.
pub fn corpus_root() -> PathBuf {
    match std::env::var("MINIONS_DURABILITY_DIR") {
        Ok(dir) if !dir.is_empty() => PathBuf::from(dir),
        _ => std::env::temp_dir().join(format!("minions-durability-{}", std::process::id())),
    }
}

/// A fresh case directory under the corpus root (wiped if it exists, so
/// re-runs are clean; left behind on panic for post-mortem upload).
pub fn case_dir(name: &str) -> PathBuf {
    let dir = corpus_root().join(name);
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).expect("create case dir");
    dir
}

/// Read a WAL as its record lines (trailing newline stripped per line).
pub fn read_wal_lines(path: &Path) -> Vec<String> {
    let text = fs::read_to_string(path).expect("read wal");
    text.lines().map(str::to_string).collect()
}

/// Write a truncated/torn WAL: `lines` verbatim (newline-terminated),
/// then `torn_tail` raw bytes with no terminator — the on-disk state a
/// crash mid-append leaves behind.
pub fn write_wal(path: &Path, lines: &[String], torn_tail: Option<&[u8]>) {
    let mut f = fs::File::create(path).expect("create wal");
    for line in lines {
        f.write_all(line.as_bytes()).unwrap();
        f.write_all(b"\n").unwrap();
    }
    if let Some(tail) = torn_tail {
        f.write_all(tail).unwrap();
    }
    f.flush().unwrap();
}

/// `MINIONS_WAL_MODE=segmented` flips the durability suite's runners to
/// the shared segmented WAL (the CI matrix runs both backends); unset
/// (or any other value) means per-session files.
pub fn segmented_mode() -> bool {
    std::env::var("MINIONS_WAL_MODE").map(|v| v == "segmented").unwrap_or(false)
}

/// Every segment file under `dir`, in epoch order — the order the
/// boot-time scan reads them, so concatenating their records gives the
/// global append order.
pub fn segment_files(dir: &Path) -> Vec<PathBuf> {
    let mut epochs: Vec<u64> = fs::read_dir(dir)
        .expect("read segment dir")
        .filter_map(|e| segment::parse_segment_name(e.ok()?.file_name().to_str()?))
        .collect();
    epochs.sort_unstable();
    epochs.iter().map(|e| segment::segment_path(dir, *e)).collect()
}

/// One session's record lines collected from the shared segments in
/// storage order. Lines keep their full framing (`crc`, `seq`, `sid`,
/// `body`), so they are byte-comparable across kill/recover cycles.
pub fn segment_lines_for(dir: &Path, sid: u64) -> Vec<String> {
    let mut out = Vec::new();
    for path in segment_files(dir) {
        for line in read_wal_lines(&path) {
            let v = Json::parse(&line).expect("parse segment record");
            if v.get("sid").and_then(Json::as_u64) == Some(sid) {
                out.push(line);
            }
        }
    }
    out
}

/// A session's record lines regardless of backend: the per-session
/// file's lines verbatim, or its records gathered from the shared
/// segments.
pub fn session_lines(dir: &Path, id: u64) -> Vec<String> {
    if segmented_mode() {
        segment_lines_for(dir, id)
    } else {
        read_wal_lines(&wal::wal_path(dir, id))
    }
}

/// Write a session's crash state the way the active backend would leave
/// it: a per-session WAL file, or a single `wal-0.seg` shared segment
/// holding the same framed lines (plus an optional torn tail).
pub fn write_session_wal(dir: &Path, id: u64, lines: &[String], torn_tail: Option<&[u8]>) {
    if segmented_mode() {
        write_wal(&segment::segment_path(dir, 0), lines, torn_tail);
    } else {
        write_wal(&wal::wal_path(dir, id), lines, torn_tail);
    }
}

/// Encode `body` as record `seq` of session `id` in the active
/// backend's framing, newline-stripped to match `read_wal_lines`.
pub fn encode_record_line(id: u64, seq: u64, body: &Json) -> String {
    let line = if segmented_mode() {
        segment::encode_seg_record(id, seq, body)
    } else {
        wal::encode_record(seq, body)
    };
    line.trim_end().to_string()
}

/// Re-frame per-session (or foreign-sid) record lines as session `sid`
/// segment records, `seq` renumbered from zero — what `import` writes
/// when a legacy file migrates into the shared segments.
pub fn reframe_segmented(lines: &[String], sid: u64) -> Vec<String> {
    lines
        .iter()
        .enumerate()
        .map(|(i, line)| {
            let v = Json::parse(line).expect("parse record");
            let body = v.get("body").expect("record body");
            segment::encode_seg_record(sid, i as u64, body).trim_end().to_string()
        })
        .collect()
}
