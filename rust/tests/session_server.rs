//! Session-scheduler + streaming-server integration tests (artifact-free:
//! stub protocols and the deterministic pseudo backend stand in for
//! compiled weights, so these run in every environment).
//!
//! What they pin down:
//! - a single-worker `SessionRunner` **interleaves** `step()` calls of
//!   two concurrent sessions round-robin instead of running one to
//!   completion first;
//! - `GET /v1/sessions/:id/events` streams `SessionEvent` JSON lines
//!   *before* the session completes (two lines are read while the
//!   session is provably still running behind a gate);
//! - the session path and the blocking `/v1/query` path agree
//!   bit-for-bit on the same sample;
//! - a repeated-chunk workload drives nonzero `cache_hits` on
//!   `/metrics`, with identical responses for the cached re-run;
//! - the HTTP edge survives hostile framing: bodies split across writes,
//!   peers that close mid-body, oversized or malformed `Content-Length`,
//!   and headers dribbled one byte at a time;
//! - the `minions gateway` front door proxies requests byte-identically
//!   to a direct worker hit (bodies and event lines agree).

mod testutil;

use anyhow::Result;
use minions::cache::ChunkCache;
use minions::cost::Ledger;
use minions::data::{self, Sample};
use minions::model::{local, remote, LocalLm, RemoteLm};
use minions::protocol::{
    MinionS, MinionsConfig, Outcome, Protocol, ProtocolFactory, ProtocolSession, ProtocolSpec,
    SessionEvent,
};
use minions::runtime::Manifest;
use minions::sched::DynamicBatcher;
use minions::server::gateway::{GatewayConfig, GatewayServer};
use minions::server::session::SessionRunner;
use minions::server::{
    http_delete_raw, http_get, http_get_raw, http_post, http_post_raw, Metrics, Server,
    ServerState,
};
use minions::util::json::Json;
use minions::util::rng::Rng;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};
use testutil::{Gate, PseudoBackend};

// ---------------------------------------------------------------------
// Stub stepped protocol: N chat-style rounds, then finalize. An optional
// gate (shared `testutil::Gate`) blocks a chosen step until the test
// releases it.
// ---------------------------------------------------------------------

struct Stepped {
    rounds: usize,
    /// (step number, gate): that step blocks until the gate opens
    gate: Option<(usize, Gate)>,
}

impl Protocol for Stepped {
    fn name(&self) -> String {
        format!("stepped[{}]", self.rounds)
    }

    fn session(&self, sample: &Sample) -> Box<dyn ProtocolSession> {
        Box::new(SteppedSession {
            truth: sample.query.answer.clone(),
            rounds: self.rounds,
            gate: self.gate.clone(),
            step: 0,
        })
    }
}

struct SteppedSession {
    truth: data::Answer,
    rounds: usize,
    gate: Option<(usize, Gate)>,
    step: usize,
}

impl ProtocolSession for SteppedSession {
    fn step(&mut self, _rng: &mut Rng) -> Result<SessionEvent> {
        self.step += 1;
        if let Some((gated_step, gate)) = &self.gate {
            if self.step == *gated_step {
                gate.wait();
            }
        }
        if self.step <= self.rounds {
            Ok(SessionEvent::RoundExecuted {
                round: self.step,
                jobs: 1,
                survivors: 0,
            })
        } else {
            let mut ledger = Ledger::default();
            ledger.remote_msg(10, 1);
            Ok(SessionEvent::Finalized(Outcome {
                answer: self.truth.clone(),
                ledger,
                rounds: self.rounds,
                transcript: vec![],
            }))
        }
    }
}

// ---------------------------------------------------------------------
// Interleaving: one worker, two sessions → strict round-robin steps.
// ---------------------------------------------------------------------

#[test]
fn one_worker_interleaves_two_concurrent_sessions() {
    let runner = SessionRunner::new(1);
    let gate = Gate::default();
    let proto: Arc<dyn Protocol> = Arc::new(Stepped {
        rounds: 3,
        gate: Some((1, gate.clone())),
    });
    let ds = data::micro::multistep_sweep(1, 2, 5);
    // both sessions are queued before the gate lets the first step finish,
    // so the schedule below is deterministic
    let a = runner.spawn(&proto, &ds.samples[0], Rng::seed_from(1), None);
    let b = runner.spawn(&proto, &ds.samples[1], Rng::seed_from(2), None);
    gate.open();
    a.wait_done();
    b.wait_done();
    // 4 steps each (3 rounds + finalize), strictly alternating
    let trace = runner.step_trace();
    assert_eq!(trace.len(), 8, "trace: {trace:?}");
    let expected: Vec<u64> = (0..8).map(|i| if i % 2 == 0 { a.id } else { b.id }).collect();
    assert_eq!(trace, expected, "steps must interleave round-robin");
    assert_eq!(runner.active(), 0);
    assert_eq!(runner.started_total(), 2);
}

// ---------------------------------------------------------------------
// Streaming: ≥2 event lines arrive while the session is still running.
// ---------------------------------------------------------------------

/// Incremental chunked-transfer reader (http_get would block to EOF).
struct ChunkedLines {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl ChunkedLines {
    fn open(addr: &str, path: &str) -> ChunkedLines {
        let mut stream = TcpStream::connect(addr).unwrap();
        let req = format!("GET {path} HTTP/1.1\r\nHost: minions\r\nConnection: close\r\n\r\n");
        stream.write_all(req.as_bytes()).unwrap();
        let mut r = ChunkedLines {
            stream,
            buf: Vec::new(),
        };
        // consume the response headers
        while !r.buf.windows(4).any(|w| w == b"\r\n\r\n") {
            assert!(r.fill(), "headers never completed");
        }
        let pos = r.buf.windows(4).position(|w| w == b"\r\n\r\n").unwrap();
        r.buf.drain(..pos + 4);
        r
    }

    fn fill(&mut self) -> bool {
        let mut tmp = [0u8; 1024];
        match self.stream.read(&mut tmp) {
            Ok(0) | Err(_) => false,
            Ok(n) => {
                self.buf.extend_from_slice(&tmp[..n]);
                true
            }
        }
    }

    /// Next chunk payload (one event line), or None at end-of-stream.
    fn next_line(&mut self) -> Option<String> {
        loop {
            // "<hex>\r\n<payload>\r\n"
            if let Some(hdr_end) = self.buf.windows(2).position(|w| w == b"\r\n") {
                let size_hex = std::str::from_utf8(&self.buf[..hdr_end]).ok()?;
                let size = usize::from_str_radix(size_hex.trim(), 16).ok()?;
                if size == 0 {
                    return None;
                }
                let total = hdr_end + 2 + size + 2;
                if self.buf.len() >= total {
                    let payload =
                        String::from_utf8_lossy(&self.buf[hdr_end + 2..hdr_end + 2 + size])
                            .trim_end()
                            .to_string();
                    self.buf.drain(..total);
                    return Some(payload);
                }
            }
            if !self.fill() {
                return None;
            }
        }
    }
}

#[test]
fn events_endpoint_streams_lines_before_completion() {
    let gate = Gate::default();
    // steps 1 and 2 emit rounds; step 3 (the last round) blocks on the
    // gate, so exactly two lines can exist while the session runs
    let proto: Arc<dyn Protocol> = Arc::new(Stepped {
        rounds: 3,
        gate: Some((3, gate.clone())),
    });
    let ds = data::micro::multistep_sweep(1, 1, 5);
    let mut datasets = HashMap::new();
    datasets.insert("micro".to_string(), ds);
    let mut protocols: HashMap<String, Arc<dyn Protocol>> = HashMap::new();
    protocols.insert("stepped".to_string(), proto);
    let state = minions::server::state_with(datasets, protocols, 7);
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    // serve forever on a detached thread: a streaming connection stays
    // open across other requests, so a max-requests budget would race
    std::thread::spawn(move || server.serve(None));

    let resp = http_post(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":0,"protocol":"stepped"}"#,
    )
    .unwrap();
    let sid = Json::parse(&resp)
        .unwrap()
        .get("session_id")
        .and_then(Json::as_u64)
        .unwrap();

    let mut lines = ChunkedLines::open(&addr, &format!("/v1/sessions/{sid}/events"));
    let first = lines.next_line().expect("first event line");
    let second = lines.next_line().expect("second event line");
    assert!(first.contains("\"round_executed\"") && first.contains("\"round\":1"), "{first}");
    assert!(second.contains("\"round\":2"), "{second}");
    // both lines arrived while the session is provably still running
    // (its next step is parked on the gate)
    let status = http_get(&addr, &format!("/v1/sessions/{sid}")).unwrap();
    assert!(status.contains("\"running\""), "got: {status}");

    gate.open();
    let mut saw_final = false;
    while let Some(line) = lines.next_line() {
        saw_final = line.contains("\"finalized\"");
    }
    assert!(saw_final, "stream must end with the finalized event");
}

// ---------------------------------------------------------------------
// Real-protocol stack on the pseudo backend (`testutil::PseudoBackend`):
// session path == query path, and repeated-chunk workloads hit the
// cache.
// ---------------------------------------------------------------------

fn cached_minions_state() -> (Arc<ServerState>, Arc<DynamicBatcher>) {
    let batcher = DynamicBatcher::new(Arc::new(PseudoBackend), Duration::from_millis(2));
    let cache = ChunkCache::new(4096);
    let manifest = Manifest::stub_for_tests(&[64, 128, 256, 1024], vec![1.0, 0.5, 0.25]);
    let local = Arc::new(
        LocalLm::with_cache(
            Arc::clone(&batcher),
            &manifest,
            local::LLAMA_3B,
            Some(Arc::clone(&cache)),
        )
        .unwrap(),
    );
    let remote = Arc::new(
        RemoteLm::with_cache(
            Arc::clone(&batcher),
            &manifest,
            remote::GPT_4O,
            Some(Arc::clone(&cache)),
        )
        .unwrap(),
    );
    let mut datasets = HashMap::new();
    datasets.insert("micro".to_string(), data::micro::multistep_sweep(2, 3, 3));
    let mut protocols: HashMap<String, Arc<dyn Protocol>> = HashMap::new();
    protocols.insert(
        "minions".to_string(),
        Arc::new(MinionS::new(local, remote, MinionsConfig::default())),
    );
    let state = Arc::new(ServerState {
        datasets,
        protocols,
        aliases: HashMap::new(),
        factory: None,
        metrics: Arc::new(Metrics::default()),
        seed: 11,
        batcher: Some(Arc::clone(&batcher)),
        cache: Some(cache),
        engine: None,
        sessions: SessionRunner::new(2),
        max_sessions: 0,
    });
    (state, batcher)
}

/// A spec-serving state: no pre-built instances beyond the resolved
/// `minions` alias — everything else arrives as an inline spec through
/// the factory (PseudoBackend stack, cache off).
fn spec_server_state() -> (Arc<ServerState>, Arc<DynamicBatcher>) {
    let batcher = DynamicBatcher::new(Arc::new(PseudoBackend), Duration::from_millis(2));
    let manifest = Manifest::stub_for_tests(&[64, 128, 256, 1024], vec![1.0, 0.5, 0.25]);
    let factory = Arc::new(ProtocolFactory::new(
        Arc::new(PseudoBackend),
        Arc::clone(&batcher),
        manifest,
        None,
    ));
    let mut aliases = HashMap::new();
    aliases.insert(
        "minions".to_string(),
        ProtocolSpec::minions("llama-3b", "gpt-4o"),
    );
    let mut protocols: HashMap<String, Arc<dyn Protocol>> = HashMap::new();
    for (name, spec) in &aliases {
        protocols.insert(name.clone(), factory.resolve(spec).unwrap());
    }
    let mut datasets = HashMap::new();
    datasets.insert("micro".to_string(), data::micro::multistep_sweep(2, 3, 3));
    let state = Arc::new(ServerState {
        datasets,
        protocols,
        aliases,
        factory: Some(factory),
        metrics: Arc::new(Metrics::default()),
        seed: 11,
        batcher: Some(Arc::clone(&batcher)),
        cache: None,
        engine: None,
        sessions: SessionRunner::new(2),
        max_sessions: 0,
    });
    (state, batcher)
}

#[test]
fn repeated_chunk_workload_hits_cache_and_matches_query_path() {
    let (state, batcher) = cached_minions_state();
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    let body = r#"{"dataset":"micro","sample":1,"protocol":"minions"}"#;
    // blocking run (cold), blocking re-run (warm: same chunks, same keys)
    let cold = http_post(&addr, "/v1/query", body).unwrap();
    let warm = http_post(&addr, "/v1/query", body).unwrap();
    let cj = Json::parse(&cold).unwrap();
    let wj = Json::parse(&warm).unwrap();
    for field in ["correct", "rounds", "usd", "remote_prefill", "remote_decode"] {
        assert_eq!(
            cj.get(field).map(|v| v.to_string()),
            wj.get(field).map(|v| v.to_string()),
            "cached re-run must be identical ({field})"
        );
    }

    // session path over the same sample: identical result again
    let resp = http_post(&addr, "/v1/sessions", body).unwrap();
    let sid = Json::parse(&resp)
        .unwrap()
        .get("session_id")
        .and_then(Json::as_u64)
        .unwrap();
    let events = http_get(&addr, &format!("/v1/sessions/{sid}/events")).unwrap();
    assert!(events.contains("\"finalized\""), "got: {events}");
    for field in ["\"correct\"", "\"remote_prefill\""] {
        let frag = cj
            .get(field.trim_matches('"'))
            .map(|v| format!("{field}:{v}"))
            .unwrap();
        assert!(events.contains(&frag), "session diverged: {frag} not in {events}");
    }

    // the acceptance gauge: nonzero cache_hits on a repeated-chunk load
    let metrics = http_get(&addr, "/metrics").unwrap();
    let m = Json::parse(&metrics).unwrap();
    let hits = m.get("cache_hits").unwrap().as_u64().unwrap();
    assert!(hits > 0, "expected cache hits, got metrics {metrics}");
    assert!(m.get("batch_cached_rows").unwrap().as_u64().unwrap() > 0);
    assert_eq!(m.get("sessions_started").unwrap().as_u64(), Some(1));
    batcher.stop();
}

// ---------------------------------------------------------------------
// Cancellation: DELETE mid-run returns 200 and the session reaches
// Cancelled without leaking its scheduler slot (per-lane depth gauges
// and sessions_active both return to zero); cancelling a done session
// is the documented 409 no-op; unknown ids are 404.
// ---------------------------------------------------------------------

/// ServerState with the gated stub protocol *and* a batcher attached,
/// so `/metrics` exposes the per-lane depth gauges the leak asserts use.
fn gated_state_with_batcher(
    rounds: usize,
    gate: Option<(usize, Gate)>,
) -> (Arc<ServerState>, Arc<DynamicBatcher>) {
    let batcher = DynamicBatcher::new(Arc::new(PseudoBackend), Duration::from_millis(2));
    let mut datasets = HashMap::new();
    datasets.insert("micro".to_string(), data::micro::multistep_sweep(1, 2, 5));
    let mut protocols: HashMap<String, Arc<dyn Protocol>> = HashMap::new();
    protocols.insert("stepped".to_string(), Arc::new(Stepped { rounds, gate }));
    let state = Arc::new(ServerState {
        datasets,
        protocols,
        aliases: HashMap::new(),
        factory: None,
        metrics: Arc::new(Metrics::default()),
        seed: 7,
        batcher: Some(Arc::clone(&batcher)),
        cache: None,
        engine: None,
        sessions: SessionRunner::new(1),
        max_sessions: 0,
    });
    (state, batcher)
}

#[test]
fn delete_mid_run_returns_200_and_frees_the_slot() {
    let gate = Gate::default();
    // 100 rounds with step 2 gated: the session provably cannot finish
    // before the test both cancels it and opens the gate
    let (state, batcher) = gated_state_with_batcher(100, Some((2, gate.clone())));
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    let resp = http_post(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":0,"protocol":"stepped"}"#,
    )
    .unwrap();
    let sid = Json::parse(&resp)
        .unwrap()
        .get("session_id")
        .and_then(Json::as_u64)
        .unwrap();

    // DELETE while running: 200, body "cancelled" (was queued) or
    // "cancelling" (a step was in flight; converted between steps)
    let raw = http_delete_raw(&addr, &format!("/v1/sessions/{sid}")).unwrap();
    assert!(raw.starts_with("HTTP/1.1 200"), "cancel must be 200: {raw}");
    assert!(
        raw.contains("\"cancelled\"") || raw.contains("\"cancelling\""),
        "{raw}"
    );
    gate.open();

    // the session reaches the terminal Cancelled state...
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let status = http_get(&addr, &format!("/v1/sessions/{sid}")).unwrap();
        if status.contains("\"cancelled\"") {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "session never reached cancelled: {status}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    // ...with 98 rounds never run and nothing leaked: the active gauge
    // and both per-lane queue depths are back to zero
    let metrics = http_get(&addr, "/metrics").unwrap();
    let m = Json::parse(&metrics).unwrap();
    assert_eq!(m.get("sessions_active").unwrap().as_u64(), Some(0));
    assert_eq!(m.get("sessions_cancelled").unwrap().as_u64(), Some(1));
    assert_eq!(
        m.get("sched_queue_depth_interactive").unwrap().as_u64(),
        Some(0),
        "cancel leaked interactive-lane rows: {metrics}"
    );
    assert_eq!(
        m.get("sched_queue_depth_batch").unwrap().as_u64(),
        Some(0)
    );

    // cancelling the already-cancelled session: documented 409 no-op
    let raw = http_delete_raw(&addr, &format!("/v1/sessions/{sid}")).unwrap();
    assert!(raw.starts_with("HTTP/1.1 409"), "expected 409: {raw}");
    assert!(raw.contains("already terminal"), "{raw}");
    // and the event stream ends with the cancelled event
    let events = http_get(&addr, &format!("/v1/sessions/{sid}/events")).unwrap();
    assert!(events.contains("\"cancelled\""), "{events}");
    batcher.stop();
}

#[test]
fn delete_done_session_is_409_and_unknown_is_404() {
    let (state, batcher) = gated_state_with_batcher(1, None);
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    let resp = http_post(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":0,"protocol":"stepped"}"#,
    )
    .unwrap();
    let sid = Json::parse(&resp)
        .unwrap()
        .get("session_id")
        .and_then(Json::as_u64)
        .unwrap();
    // events-to-EOF is the completion barrier
    let events = http_get(&addr, &format!("/v1/sessions/{sid}/events")).unwrap();
    assert!(events.contains("\"finalized\""));

    let raw = http_delete_raw(&addr, &format!("/v1/sessions/{sid}")).unwrap();
    assert!(raw.starts_with("HTTP/1.1 409"), "done session: {raw}");
    let raw = http_delete_raw(&addr, "/v1/sessions/99999").unwrap();
    assert!(raw.starts_with("HTTP/1.1 404"), "unknown id: {raw}");
    // a cancelled metric was never incremented by the no-ops
    let metrics = http_get(&addr, "/metrics").unwrap();
    let m = Json::parse(&metrics).unwrap();
    assert_eq!(m.get("sessions_cancelled").unwrap().as_u64(), Some(0));
    batcher.stop();
}

/// Cancel a *real* MinionS run mid-flight: whichever way the race lands
/// (cancelled or already finalized), no scheduler slot and no queued
/// lane rows may leak.
#[test]
fn cancel_mid_real_minions_run_leaves_no_queued_rows() {
    let (state, batcher) = cached_minions_state();
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    let resp = http_post(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":0,"protocol":"minions"}"#,
    )
    .unwrap();
    let sid = Json::parse(&resp)
        .unwrap()
        .get("session_id")
        .and_then(Json::as_u64)
        .unwrap();
    let raw = http_delete_raw(&addr, &format!("/v1/sessions/{sid}")).unwrap();
    assert!(
        raw.starts_with("HTTP/1.1 200") || raw.starts_with("HTTP/1.1 409"),
        "cancel must be 200 (accepted) or 409 (already done): {raw}"
    );
    // wait for the terminal state, then assert nothing leaked
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let status = http_get(&addr, &format!("/v1/sessions/{sid}")).unwrap();
        if !status.contains("\"running\"") {
            break;
        }
        assert!(Instant::now() < deadline, "never left running: {status}");
        std::thread::sleep(Duration::from_millis(5));
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let metrics = http_get(&addr, "/metrics").unwrap();
        let m = Json::parse(&metrics).unwrap();
        let active = m.get("sessions_active").unwrap().as_u64().unwrap();
        let qi = m
            .get("sched_queue_depth_interactive")
            .unwrap()
            .as_u64()
            .unwrap();
        let qb = m.get("sched_queue_depth_batch").unwrap().as_u64().unwrap();
        if active == 0 && qi == 0 && qb == 0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "leaked slots/rows: active={active} qi={qi} qb={qb}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    batcher.stop();
}

// ---------------------------------------------------------------------
// Coverage satellites: 404 after TTL eviction over HTTP, and a
// malformed session body is a counted 400.
// ---------------------------------------------------------------------

#[test]
fn evicted_session_polls_404_after_ttl() {
    let ttl = Duration::from_millis(50);
    let mut datasets = HashMap::new();
    datasets.insert("micro".to_string(), data::micro::multistep_sweep(1, 2, 5));
    let mut protocols: HashMap<String, Arc<dyn Protocol>> = HashMap::new();
    protocols.insert(
        "stepped".to_string(),
        Arc::new(Stepped {
            rounds: 1,
            gate: None,
        }),
    );
    let state = Arc::new(ServerState {
        datasets,
        protocols,
        aliases: HashMap::new(),
        factory: None,
        metrics: Arc::new(Metrics::default()),
        seed: 7,
        batcher: None,
        cache: None,
        engine: None,
        sessions: SessionRunner::with_config(1, ttl),
        max_sessions: 0,
    });
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    let body = r#"{"dataset":"micro","sample":0,"protocol":"stepped"}"#;
    let resp = http_post(&addr, "/v1/sessions", body).unwrap();
    let sid = Json::parse(&resp)
        .unwrap()
        .get("session_id")
        .and_then(Json::as_u64)
        .unwrap();
    let events = http_get(&addr, &format!("/v1/sessions/{sid}/events")).unwrap();
    assert!(events.contains("\"finalized\""));
    // pollable before the TTL...
    let raw = http_get_raw(&addr, &format!("/v1/sessions/{sid}")).unwrap();
    assert!(raw.starts_with("HTTP/1.1 200"), "{raw}");
    // ...then evicted: a later spawn reaps, and the poll is a 404
    std::thread::sleep(ttl + Duration::from_millis(100));
    let resp = http_post(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":1,"protocol":"stepped"}"#,
    )
    .unwrap();
    assert!(resp.contains("session_id"), "{resp}");
    let raw = http_get_raw(&addr, &format!("/v1/sessions/{sid}")).unwrap();
    assert!(
        raw.starts_with("HTTP/1.1 404") && raw.contains("unknown session"),
        "evicted session must 404: {raw}"
    );
}

#[test]
fn malformed_session_body_is_400_and_counted() {
    let (state, batcher) = gated_state_with_batcher(1, None);
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    let raw = http_post_raw(&addr, "/v1/sessions", "{not json").unwrap();
    assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");
    assert!(raw.contains("bad json"), "{raw}");
    // missing required field is a 400 too
    let raw = http_post_raw(&addr, "/v1/sessions", r#"{"sample":0}"#).unwrap();
    assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");
    assert!(raw.contains("missing 'dataset'"), "{raw}");

    let metrics = http_get(&addr, "/metrics").unwrap();
    let m = Json::parse(&metrics).unwrap();
    assert_eq!(m.get("errors").unwrap().as_u64(), Some(2));
    assert_eq!(m.get("sessions_started").unwrap().as_u64(), Some(0));
    batcher.stop();
}

// ---------------------------------------------------------------------
// Typed-spec API: unknown protocols are 400s (404 stays reserved for
// session ids), inline specs are validated and run per request, and
// GET /v1/protocols documents the surface.
// ---------------------------------------------------------------------

#[test]
fn unknown_protocol_is_400_listing_registered_aliases() {
    let (state, batcher) = gated_state_with_batcher(1, None);
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    let raw = http_post_raw(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":0,"protocol":"nope"}"#,
    )
    .unwrap();
    assert!(raw.starts_with("HTTP/1.1 400"), "client error, not 404: {raw}");
    assert!(raw.contains("unknown protocol 'nope'"), "{raw}");
    assert!(raw.contains("stepped"), "must list registered aliases: {raw}");
    // unknown dataset / out-of-range sample are 400s too
    let raw = http_post_raw(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"zzz","sample":0,"protocol":"stepped"}"#,
    )
    .unwrap();
    assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");
    let raw = http_post_raw(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":99,"protocol":"stepped"}"#,
    )
    .unwrap();
    assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");
    // ...while 404 remains the unknown-session-id status
    let raw = http_get_raw(&addr, "/v1/sessions/424242").unwrap();
    assert!(raw.starts_with("HTTP/1.1 404"), "{raw}");
    batcher.stop();
}

/// Acceptance: two concurrent sessions carrying *different* inline specs
/// (different local-profile rungs) run on one server and both finalize.
#[test]
fn concurrent_inline_specs_with_different_rungs_both_finalize() {
    let (state, batcher) = spec_server_state();
    let server = Server::bind(state, "127.0.0.1:0", 4).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    // both sessions admitted before either is driven: the worker pool
    // interleaves their steps, so they really do run concurrently
    let bodies = [
        r#"{"dataset":"micro","sample":0,"spec":{"kind":"minions","local":"llama-3b","remote":"gpt-4o"}}"#,
        r#"{"dataset":"micro","sample":1,"spec":{"kind":"minions","local":"llama-1b","remote":"gpt-4o"}}"#,
    ];
    let mut sids = Vec::new();
    for body in bodies {
        let resp = http_post(&addr, "/v1/sessions", body).unwrap();
        let sid = Json::parse(&resp)
            .unwrap()
            .get("session_id")
            .and_then(Json::as_u64)
            .unwrap_or_else(|| panic!("session admitted: {resp}"));
        sids.push(sid);
    }
    for sid in sids {
        let events = http_get(&addr, &format!("/v1/sessions/{sid}/events")).unwrap();
        assert!(events.contains("\"finalized\""), "session {sid}: {events}");
    }
    let metrics = http_get(&addr, "/metrics").unwrap();
    let m = Json::parse(&metrics).unwrap();
    assert_eq!(m.get("sessions_started").unwrap().as_u64(), Some(2));
    assert_eq!(m.get("sessions_active").unwrap().as_u64(), Some(0));
    batcher.stop();
}

#[test]
fn invalid_inline_specs_are_structured_400s() {
    let (state, batcher) = spec_server_state();
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    // unknown kind: same message the CLI prints for --protocol minionz
    let raw = http_post_raw(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":0,"spec":{"kind":"minionz"}}"#,
    )
    .unwrap();
    assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");
    assert!(raw.contains("unknown protocol 'minionz'"), "{raw}");
    assert!(raw.contains("rag-dense"), "must list supported kinds: {raw}");
    assert!(raw.contains("auto"), "unknown-kind 400 must name auto: {raw}");
    // unknown profile rung
    let raw = http_post_raw(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":0,"spec":{"kind":"minions","local":"llama-9t"}}"#,
    )
    .unwrap();
    assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");
    assert!(raw.contains("unknown local profile"), "{raw}");
    // typo'd field name
    let raw = http_post_raw(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":0,"spec":{"kind":"minions","max_round":3}}"#,
    )
    .unwrap();
    assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");
    assert!(raw.contains("unknown spec field"), "{raw}");
    // ambiguous selection
    let raw = http_post_raw(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":0,"protocol":"minions","spec":{"kind":"minions"}}"#,
    )
    .unwrap();
    assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");
    assert!(raw.contains("not both"), "{raw}");
    // malformed auto specs take the same structured path
    let raw = http_post_raw(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":0,"spec":{"kind":"auto","route_weights":"fast"}}"#,
    )
    .unwrap();
    assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");
    assert!(raw.contains("route_weights"), "{raw}");
    let raw = http_post_raw(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":0,"spec":{"kind":"auto","budget":3}}"#,
    )
    .unwrap();
    assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");
    assert!(raw.contains("unknown auto spec field 'budget'"), "{raw}");

    let metrics = http_get(&addr, "/metrics").unwrap();
    let m = Json::parse(&metrics).unwrap();
    assert_eq!(m.get("errors").unwrap().as_u64(), Some(6));
    assert_eq!(m.get("sessions_started").unwrap().as_u64(), Some(0));
    batcher.stop();
}

#[test]
fn protocols_endpoint_lists_aliases_kinds_and_schema() {
    let (state, batcher) = spec_server_state();
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    let body = http_get(&addr, "/v1/protocols").unwrap();
    let j = Json::parse(&body).unwrap();
    // the registered alias appears with its canonical spec
    let alias = j.get("aliases").and_then(|a| a.get("minions")).unwrap();
    assert_eq!(alias.get("kind").and_then(Json::as_str), Some("minions"));
    assert_eq!(alias.get("local").and_then(Json::as_str), Some("llama-3b"));
    // kinds + per-field schema for composing inline specs
    let kinds = j.get("kinds").and_then(Json::as_arr).unwrap();
    assert!(kinds.iter().any(|k| k.as_str() == Some("rag-bm25")));
    assert_eq!(j.get("accepts_inline_specs").and_then(Json::as_bool), Some(true));
    let schema = j.get("schema").unwrap();
    for field in ["local", "remote", "strategy", "top_k"] {
        assert!(schema.get(field).is_some(), "schema missing {field}: {body}");
    }
    // the auto meta-kind is documented alongside, with per-field
    // help/defaults for composing a {"kind":"auto"} spec
    let auto = j.get("auto").unwrap_or_else(|| panic!("no auto section: {body}"));
    for field in ["kind", "local", "remote", "route_weights", "probe_budget", "allowed"] {
        let f = auto.get(field).unwrap_or_else(|| panic!("auto missing {field}: {body}"));
        assert!(f.get("help").is_some() && f.get("default").is_some(), "{body}");
    }
    batcher.stop();
}

/// Acceptance: an inline `{"kind":"auto"}` session routes through the
/// difficulty probe, runs on the chosen rung, and every surface — the
/// create response, the status body, the cost-accounted query reply,
/// `/metrics` — reports the *resolved* protocol, never the literal
/// `auto`.
#[test]
fn auto_sessions_route_and_account_on_the_resolved_rung() {
    let (state, batcher) = spec_server_state();
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    // quality-first over {local, minions} deterministically escalates
    let resp = http_post(
        &addr,
        "/v1/sessions",
        r#"{"dataset":"micro","sample":0,"spec":{"kind":"auto","local":"llama-3b","route_weights":"0:0:1","allowed":["local","minions"]}}"#,
    )
    .unwrap();
    let j = Json::parse(&resp).unwrap();
    let sid = j.get("session_id").and_then(Json::as_u64).unwrap_or_else(|| panic!("{resp}"));
    assert_ne!(j.get("protocol").and_then(Json::as_str), Some("auto"), "{resp}");
    let routed = j.get("routed").unwrap_or_else(|| panic!("no routed payload: {resp}"));
    assert_eq!(routed.get("chosen_kind").and_then(Json::as_str), Some("minions"));
    assert!(routed.get("features").is_some() && routed.get("scores").is_some(), "{resp}");
    let events = http_get(&addr, &format!("/v1/sessions/{sid}/events")).unwrap();
    assert!(events.contains("\"finalized\""), "{events}");
    let status = Json::parse(&http_get(&addr, &format!("/v1/sessions/{sid}")).unwrap()).unwrap();
    assert_ne!(status.get("protocol").and_then(Json::as_str), Some("auto"));
    assert_eq!(
        status.get("routed").and_then(|r| r.get("chosen_kind")).and_then(Json::as_str),
        Some("minions")
    );

    // the blocking query path routes too; cost fields account the
    // resolved rung (cost-first stays on the zero-dollar local rung)
    let reply = http_post(
        &addr,
        "/v1/query",
        r#"{"dataset":"micro","sample":1,"spec":{"kind":"auto","local":"llama-3b","route_weights":"0:1:0"}}"#,
    )
    .unwrap();
    let q = Json::parse(&reply).unwrap();
    assert_ne!(q.get("protocol").and_then(Json::as_str), Some("auto"), "{reply}");
    assert_eq!(
        q.get("routed").and_then(|r| r.get("chosen_kind")).and_then(Json::as_str),
        Some("local"),
        "{reply}"
    );
    assert_eq!(q.get("usd").and_then(Json::as_f64), Some(0.0), "{reply}");

    let m = Json::parse(&http_get(&addr, "/metrics").unwrap()).unwrap();
    assert_eq!(m.get("router_requests").unwrap().as_u64(), Some(2));
    assert_eq!(m.get("router_chosen_minions").unwrap().as_u64(), Some(1));
    assert_eq!(m.get("router_chosen_local").unwrap().as_u64(), Some(1));
    assert_eq!(m.get("router_chosen_remote").unwrap().as_u64(), Some(0));
    batcher.stop();
}

// ---------------------------------------------------------------------
// HTTP-edge torture: hostile framing must produce an explicit status (or
// an explicit counted drop), never a truncated body handed to a route.
// ---------------------------------------------------------------------

/// Write raw request pieces with a pause between them (so the server
/// observes genuinely split reads), optionally FIN-ing the write side
/// mid-request, then read whatever response arrives to EOF. An empty
/// return means the server (correctly) sent nothing.
fn raw_pieces(addr: &str, pieces: &[&str], delay_ms: u64, close_early: bool) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    for p in pieces {
        stream.write_all(p.as_bytes()).unwrap();
        std::thread::sleep(Duration::from_millis(delay_ms));
    }
    if close_early {
        stream.shutdown(std::net::Shutdown::Write).unwrap();
    }
    stream
        .set_read_timeout(Some(Duration::from_secs(20)))
        .unwrap();
    let mut resp = String::new();
    let _ = stream.read_to_string(&mut resp);
    resp
}

/// Poll `/metrics` until the named counter reaches `want` (connection
/// handling is pooled, so error accounting is asynchronous).
fn wait_for_counter(addr: &str, key: &str, want: u64) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let m = Json::parse(&http_get(addr, "/metrics").unwrap()).unwrap();
        let got = m.get(key).and_then(Json::as_u64).unwrap_or(0);
        if got >= want {
            return;
        }
        assert!(Instant::now() < deadline, "{key} stuck at {got}, want {want}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn body_split_across_writes_still_parses() {
    let (state, batcher) = gated_state_with_batcher(1, None);
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    let body = r#"{"dataset":"micro","sample":0,"protocol":"stepped"}"#;
    let head = format!(
        "POST /v1/sessions HTTP/1.1\r\nHost: minions\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    // headers in one write, then the body in two halves 30ms apart: the
    // server must keep reading until Content-Length bytes have arrived
    let (a, b) = body.split_at(body.len() / 2);
    let resp = raw_pieces(&addr, &[&head, a, b], 30, false);
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(resp.contains("session_id"), "{resp}");
    batcher.stop();
}

#[test]
fn peer_close_mid_body_is_counted_and_never_reaches_a_route() {
    let (state, batcher) = gated_state_with_batcher(1, None);
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    // claim 100 bytes, send 10, hang up: no reply is possible (the
    // socket is gone), but the truncated body must not be routed — it
    // used to arrive looking complete and parse as garbage
    let head = "POST /v1/sessions HTTP/1.1\r\nHost: minions\r\nContent-Length: 100\r\n\r\n";
    let resp = raw_pieces(&addr, &[head, r#"{"dataset""#], 30, true);
    assert!(resp.is_empty(), "no response possible after FIN: {resp:?}");
    wait_for_counter(&addr, "errors", 1);
    let m = Json::parse(&http_get(&addr, "/metrics").unwrap()).unwrap();
    assert_eq!(m.get("sessions_started").unwrap().as_u64(), Some(0));
    batcher.stop();
}

#[test]
fn oversized_body_is_413_before_any_allocation() {
    let (state, batcher) = gated_state_with_batcher(1, None);
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    // 9 MiB claimed against the 8 MiB cap: refused from the header alone,
    // without waiting for (or buffering) a single body byte
    let head = format!(
        "POST /v1/sessions HTTP/1.1\r\nHost: minions\r\nContent-Length: {}\r\n\r\n",
        9 << 20
    );
    let resp = raw_pieces(&addr, &[head.as_str()], 0, false);
    assert!(resp.starts_with("HTTP/1.1 413"), "{resp}");
    assert!(resp.contains("exceeds"), "{resp}");
    wait_for_counter(&addr, "errors", 1);
    let m = Json::parse(&http_get(&addr, "/metrics").unwrap()).unwrap();
    assert_eq!(m.get("sessions_started").unwrap().as_u64(), Some(0));
    batcher.stop();
}

#[test]
fn malformed_and_absent_content_length_are_400s() {
    let (state, batcher) = gated_state_with_batcher(1, None);
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    // unparsable Content-Length: a 400, not a silent zero that drops the
    // body on the floor
    let head = "POST /v1/sessions HTTP/1.1\r\nHost: minions\r\nContent-Length: banana\r\n\r\n";
    let resp = raw_pieces(&addr, &[head], 0, false);
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
    assert!(resp.contains("malformed Content-Length"), "{resp}");

    // absent Content-Length on a POST: the body reads as empty and the
    // route rejects it as bad json — still a 400, never a hang
    let head = "POST /v1/sessions HTTP/1.1\r\nHost: minions\r\n\r\n{\"dataset\":\"micro\"}";
    let resp = raw_pieces(&addr, &[head], 0, false);
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
    assert!(resp.contains("bad json"), "{resp}");

    wait_for_counter(&addr, "errors", 2);
    batcher.stop();
}

#[test]
fn headers_dribbled_one_byte_at_a_time_still_complete() {
    let (state, batcher) = gated_state_with_batcher(1, None);
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    std::thread::spawn(move || server.serve(None));

    // one byte per write: the incremental terminator scan must stay
    // linear and the per-read timeout must not fire between bytes
    let req = "GET /healthz HTTP/1.1\r\nHost: m\r\n\r\n";
    let pieces: Vec<String> = req.chars().map(|c| c.to_string()).collect();
    let refs: Vec<&str> = pieces.iter().map(String::as_str).collect();
    let resp = raw_pieces(&addr, &refs, 2, false);
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(resp.contains("\"ok\""), "{resp}");
    batcher.stop();
}

// ---------------------------------------------------------------------
// Gateway proxy parity: the same request through `minions gateway` and
// against a worker directly must yield byte-identical responses —
// create bodies, error bodies, and the streamed event lines.
// ---------------------------------------------------------------------

/// Split a raw chunked-transfer response into its payload lines.
fn dechunked_lines(raw: &str) -> Vec<String> {
    let body = raw.split_once("\r\n\r\n").map(|x| x.1).unwrap_or(raw);
    let mut lines = Vec::new();
    let mut rest = body;
    while let Some((size_hex, tail)) = rest.split_once("\r\n") {
        let Ok(size) = usize::from_str_radix(size_hex.trim(), 16) else {
            break;
        };
        if size == 0 || tail.len() < size {
            break;
        }
        lines.push(tail[..size].trim_end().to_string());
        rest = tail.get(size + 2..).unwrap_or("");
    }
    lines
}

/// Zero out the wall-clock `latency_ms` field so deterministic runs on
/// different workers compare equal.
fn normalize_latency(line: &str) -> String {
    let mut out = String::new();
    let mut rest = line;
    while let Some(pos) = rest.find("\"latency_ms\":") {
        let after = pos + "\"latency_ms\":".len();
        out.push_str(&rest[..after]);
        out.push('0');
        let tail = &rest[after..];
        let end = tail
            .find(|c: char| !(c.is_ascii_digit() || ".eE+-".contains(c)))
            .unwrap_or(tail.len());
        rest = &tail[end..];
    }
    out.push_str(rest);
    out
}

#[test]
fn gateway_proxies_byte_identical_to_direct_worker() {
    // two identical single-worker stacks (same seed, same registry): one
    // hit directly, one only ever reached through the gateway
    let (state_d, batcher_d) = gated_state_with_batcher(2, None);
    let (state_g, batcher_g) = gated_state_with_batcher(2, None);
    let direct = Server::bind(state_d, "127.0.0.1:0", 2).unwrap();
    let addr_d = direct.addr.to_string();
    std::thread::spawn(move || direct.serve(None));
    let worker = Server::bind(state_g, "127.0.0.1:0", 2).unwrap();
    let addr_w = worker.addr.to_string();
    std::thread::spawn(move || worker.serve(None));

    let mut cfg = GatewayConfig::new(vec![addr_w.clone()]);
    cfg.probe_interval = Duration::from_secs(3600); // quiet during the test
    let gw = GatewayServer::bind(cfg, "127.0.0.1:0", 2).unwrap();
    let addr_g = gw.addr.to_string();
    std::thread::spawn(move || gw.serve(None));

    // create: both workers assign session id 1, so the relayed bytes
    // (status line, headers, body) must match the direct hit exactly
    let body = r#"{"dataset":"micro","sample":0,"protocol":"stepped"}"#;
    let raw_d = http_post_raw(&addr_d, "/v1/sessions", body).unwrap();
    let raw_g = http_post_raw(&addr_g, "/v1/sessions", body).unwrap();
    assert!(raw_d.starts_with("HTTP/1.1 200"), "{raw_d}");
    assert_eq!(raw_d, raw_g, "gateway must relay the worker bytes verbatim");
    let sid = Json::parse(raw_d.split("\r\n\r\n").nth(1).unwrap())
        .unwrap()
        .get("session_id")
        .and_then(Json::as_u64)
        .unwrap();

    // event streams: identical lines once the wall-clock latency field
    // is zeroed (everything else is deterministic under the fixed seed)
    let ev_d = http_get_raw(&addr_d, &format!("/v1/sessions/{sid}/events")).unwrap();
    let ev_g = http_get_raw(&addr_g, &format!("/v1/sessions/{sid}/events")).unwrap();
    let lines_d: Vec<String> = dechunked_lines(&ev_d).iter().map(|l| normalize_latency(l)).collect();
    let lines_g: Vec<String> = dechunked_lines(&ev_g).iter().map(|l| normalize_latency(l)).collect();
    assert!(!lines_d.is_empty(), "no event lines: {ev_d}");
    assert!(lines_d.last().unwrap().contains("\"finalized\""), "{lines_d:?}");
    assert_eq!(lines_d, lines_g, "event lines diverged through the gateway");

    // error parity: a malformed body produces the same 400 either way
    let err_d = http_post_raw(&addr_d, "/v1/sessions", "{not json").unwrap();
    let err_g = http_post_raw(&addr_g, "/v1/sessions", "{not json").unwrap();
    assert!(err_d.starts_with("HTTP/1.1 400"), "{err_d}");
    assert_eq!(err_d, err_g, "error responses must relay verbatim");

    // the migration endpoint is worker-internal: the front door refuses it
    let adopt = http_post_raw(&addr_g, "/v1/admin/adopt", r#"{"sid":1}"#).unwrap();
    assert!(adopt.starts_with("HTTP/1.1 400"), "{adopt}");
    assert!(adopt.contains("worker-internal"), "{adopt}");

    // fleet metrics: worker counters aggregate, gateway counters appear
    let m = Json::parse(&http_get(&addr_g, "/metrics").unwrap()).unwrap();
    assert_eq!(m.get("sessions_started").unwrap().as_u64(), Some(1));
    assert_eq!(m.get("gateway_workers").unwrap().as_u64(), Some(1));
    assert_eq!(m.get("gateway_workers_alive").unwrap().as_u64(), Some(1));
    assert!(m.get("gateway_proxied").unwrap().as_u64().unwrap() >= 3);
    batcher_d.stop();
    batcher_g.stop();
}
