//! Parallel-eval integration tests that need no compiled artifacts: a
//! deterministic pseudo-scoring backend plus a handmade manifest stand in
//! for the real weights, so these run in every environment (the tier-1
//! gate included).
//!
//! What they pin down:
//! - serial [`run_protocol`] and parallel [`run_protocol_on`] produce
//!   **bit-identical** accuracy, scores, and ledger totals at 1, 4, and 8
//!   threads (the batcher may compose batches differently — results must
//!   not care);
//! - two MinionS runs executing concurrently through the shared batcher
//!   keep batch occupancy above 0.5;
//! - a stopped batcher fails protocol runs with an error instead of
//!   hanging them.

use minions::data;
use minions::eval::{run_protocol, run_protocol_on, run_protocol_parallel, RunResult};
use minions::model::{local, remote, LocalLm, RemoteLm};
use minions::protocol::{LocalOnly, MinionS, MinionsConfig, Protocol};
use minions::runtime::{Backend, EmbedRequest, Manifest, ScoreRequest, ScoreResponse};
use minions::sched::DynamicBatcher;
use minions::util::pool::Pool;
use minions::vocab::{BATCH, CHUNK, QLEN};
use anyhow::Result;
use std::sync::Arc;
use std::time::Duration;

/// SplitMix64-style mixer for the pseudo scorer.
fn mix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic, content-sensitive, **row-independent** scorer: each
/// row's scores depend only on that row's tensors, never on which other
/// rows shared the dispatch — the property that makes dynamic batching
/// transparent to results. Scores use the full f32 mantissa so exact
/// ties (which would fall to tie-break order) are vanishingly rare.
struct PseudoBackend;

impl Backend for PseudoBackend {
    fn score(&self, req: ScoreRequest) -> Result<ScoreResponse> {
        let mut scores = vec![-1.0e30f32; BATCH * CHUNK];
        let mut lse = vec![0f32; BATCH];
        for b in 0..BATCH {
            let q0 = req.q_tokens[b * QLEN] as u64;
            let q1 = req.q_tokens[b * QLEN + 1] as u64;
            for c in 0..CHUNK {
                if req.c_mask[b * CHUNK + c] == 0.0 {
                    continue;
                }
                let t = req.c_tokens[b * CHUNK + c] as u64;
                let h = mix(
                    q0 ^ (q1 << 16) ^ (t << 32) ^ ((c as u64) << 48) ^ ((req.d as u64) << 60),
                );
                scores[b * CHUNK + c] = ((h >> 11) as f64 / (1u64 << 53) as f64 * 1.5) as f32;
            }
            lse[b] = 1.0;
        }
        Ok(ScoreResponse { scores, lse })
    }

    fn embed(&self, _req: EmbedRequest) -> Result<Vec<f32>> {
        unimplemented!("not used by these protocols")
    }

    fn name(&self) -> &'static str {
        "pseudo"
    }
}

fn stack(max_wait: Duration) -> (Arc<DynamicBatcher>, Arc<LocalLm>, Arc<RemoteLm>) {
    let batcher = DynamicBatcher::new(Arc::new(PseudoBackend), max_wait);
    // one wpos entry per capacity the profiles use (local 128, reader 1024)
    let manifest = Manifest::stub_for_tests(&[64, 128, 256, 1024], vec![1.0, 0.5, 0.25]);
    let local =
        Arc::new(LocalLm::new(Arc::clone(&batcher), &manifest, local::LLAMA_3B).unwrap());
    let remote =
        Arc::new(RemoteLm::new(Arc::clone(&batcher), &manifest, remote::GPT_4O).unwrap());
    (batcher, local, remote)
}

fn assert_identical(serial: &RunResult, par: &RunResult, label: &str) {
    assert_eq!(serial.scores, par.scores, "{label}: scores diverged");
    assert_eq!(
        serial.accuracy.to_bits(),
        par.accuracy.to_bits(),
        "{label}: accuracy diverged"
    );
    assert_eq!(serial.cost.total, par.cost.total, "{label}: ledger diverged");
    assert_eq!(serial.cost.n, par.cost.n, "{label}: sample count diverged");
    assert_eq!(serial.mean_rounds, par.mean_rounds, "{label}: rounds diverged");
    for (i, (a, b)) in serial.outcomes.iter().zip(&par.outcomes).enumerate() {
        assert_eq!(a.answer, b.answer, "{label}: answer {i} diverged");
        assert_eq!(a.ledger, b.ledger, "{label}: ledger {i} diverged");
        assert_eq!(a.rounds, b.rounds, "{label}: rounds {i} diverged");
    }
}

#[test]
fn parallel_minions_eval_is_bit_identical_at_1_4_8_threads() {
    let (batcher, local, remote) = stack(Duration::from_millis(2));
    let proto: Arc<dyn Protocol> = Arc::new(MinionS::new(
        Arc::clone(&local),
        remote,
        MinionsConfig::default(),
    ));
    // Multi-part queries force retry rounds; the context sweep exercises
    // multi-chunk decomposition — together they cover the protocol loop.
    for ds in [
        data::micro::multistep_sweep(2, 6, 3),
        data::micro::context_sweep(2, 6, 4),
    ] {
        let serial = run_protocol(proto.as_ref(), &ds, 11, true).unwrap();
        for threads in [1usize, 4, 8] {
            let pool = Pool::new(threads, threads * 2);
            let par =
                run_protocol_on(Arc::clone(&proto), &ds, 11, true, &pool).unwrap();
            assert_identical(&serial, &par, &format!("{} x{threads}", ds.name));
        }
    }
    batcher.stop();
}

#[test]
fn parallel_local_only_eval_is_bit_identical() {
    let (batcher, local, _remote) = stack(Duration::from_millis(2));
    let proto: Arc<dyn Protocol> = Arc::new(LocalOnly::new(local));
    let ds = data::micro::context_sweep(4, 8, 9);
    let serial = run_protocol(proto.as_ref(), &ds, 5, true).unwrap();
    for threads in [4usize, 8] {
        let par = run_protocol_parallel(Arc::clone(&proto), &ds, 5, true, threads).unwrap();
        assert_identical(&serial, &par, &format!("local-only x{threads}"));
    }
    batcher.stop();
}

#[test]
fn concurrent_minions_runs_keep_occupancy_above_half() {
    // 8 chunks x 1 task = a full batch per sample-round, so local rows
    // dominate the dispatch mix and occupancy stays high even before the
    // cross-run coalescing the shared batcher adds on top.
    let (batcher, local, remote) = stack(Duration::from_millis(20));
    let proto: Arc<dyn Protocol> = Arc::new(MinionS::new(
        Arc::clone(&local),
        remote,
        MinionsConfig::default(),
    ));
    let ds = data::micro::context_sweep(8, 3, 7);
    std::thread::scope(|s| {
        let a = {
            let proto = Arc::clone(&proto);
            let ds = &ds;
            s.spawn(move || run_protocol(proto.as_ref(), ds, 21, true).unwrap())
        };
        let b = {
            let proto = Arc::clone(&proto);
            let ds = &ds;
            s.spawn(move || run_protocol(proto.as_ref(), ds, 22, true).unwrap())
        };
        a.join().unwrap();
        b.join().unwrap();
    });
    let snap = batcher.snapshot();
    assert!(snap.dispatches > 0);
    assert!(
        snap.occupancy > 0.5,
        "two concurrent MinionS runs should batch efficiently, got {:.3} ({snap:?})",
        snap.occupancy
    );
    batcher.stop();
}

#[test]
fn stopped_batcher_fails_protocol_runs_instead_of_hanging() {
    let (batcher, local, _remote) = stack(Duration::from_millis(2));
    batcher.stop();
    let proto = LocalOnly::new(local);
    let ds = data::micro::multistep_sweep(1, 1, 2);
    let err = run_protocol(&proto, &ds, 3, true).unwrap_err();
    assert!(
        err.to_string().contains("stopped"),
        "expected a stopped-batcher error, got: {err}"
    );
}
