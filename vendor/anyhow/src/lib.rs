//! Offline-compatible subset of the `anyhow` error-handling API.
//!
//! The coordinator builds in an environment with no crates.io access, so
//! this shim provides exactly the slice the codebase uses: the [`Error`]
//! type, the [`Result`] alias, the [`anyhow!`] / [`bail!`] macros, and the
//! [`Context`] extension for `Result` and `Option`. Error chains are
//! flattened into a single rendered message ("context: cause"), which is
//! all the callers ever display.

use std::fmt;

/// A rendered, type-erased error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `Error` deliberately does NOT implement `std::error::Error`: that is
// what makes the blanket `From` below coherent (exactly as in upstream
// anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(render_chain(&e))
    }
}

/// Render `error: source: source...` so no information is lost when the
/// chain is flattened.
fn render_chain(e: &(dyn std::error::Error + 'static)) -> String {
    let mut out = e.to_string();
    let mut cur = e.source();
    while let Some(s) = cur {
        out.push_str(": ");
        out.push_str(&s.to_string());
        cur = s.source();
    }
    out
}

/// Shim so `anyhow::Error` converts into `Box<dyn std::error::Error>`
/// (used by binaries whose `main` returns the boxed form).
#[derive(Debug)]
struct BoxedMessage(String);

impl fmt::Display for BoxedMessage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for BoxedMessage {}

impl From<Error> for Box<dyn std::error::Error + Send + Sync + 'static> {
    fn from(e: Error) -> Self {
        Box::new(BoxedMessage(e.msg))
    }
}

impl From<Error> for Box<dyn std::error::Error + 'static> {
    fn from(e: Error) -> Self {
        Box::new(BoxedMessage(e.msg))
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    /// Anything that can become an [`Error`](crate::Error) — both real
    /// `std::error::Error` types and `Error` itself (so `.context()`
    /// works on already-anyhow results).
    pub trait ToError {
        fn into_error(self) -> crate::Error;
    }

    impl<E> ToError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl ToError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// `.context(..)` / `.with_context(..)` for `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: private::ToError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::msg(format!("{context}: {}", e.into_error())))
    }

    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {}", context(), e.into_error())))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(context()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn macros_and_display() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let n = 3;
        let e = anyhow!("got {n} items");
        assert_eq!(e.to_string(), "got 3 items");
        let e = anyhow!("{} and {}", 1, 2);
        assert_eq!(e.to_string(), "1 and 2");
    }

    #[test]
    fn bail_returns_err() {
        fn f(x: bool) -> Result<u32> {
            if x {
                bail!("nope: {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "nope: 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("disk on fire"));
    }

    #[test]
    fn context_on_result_option_and_error() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: disk on fire");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(e.to_string(), "missing field");

        let r: Result<(), Error> = Err(anyhow!("inner"));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }
}
