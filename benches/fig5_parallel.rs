//! Reproduces Figure 5 (parallel-workload knobs: tasks/round, samples,
//! chunk granularity) and Figure 4 (capacity vs accuracy + communication
//! efficiency / information-bottleneck view).
use minions::exp::Exp;
use minions::util::cli::Cli;

fn main() {
    let cli = Cli::new("fig5_parallel", "Figures 4-5 reproduction")
        .opt("backend", "pjrt | native (equivalence asserted by tests)", Some("native"))
        .opt("n", "samples per point", Some("16"))
        .opt("seed", "seed", Some("42"));
    let a = cli.parse();
    let n = a.parse_num("n", 16);
    let mut exp = Exp::new(a.get_or("backend", "pjrt"), a.parse_num("seed", 42)).expect("startup");
    println!("== Figure 4: model-size series ==");
    println!("{}", exp.fig4(n).unwrap());
    println!("== Figure 5: parallel-workload knobs ==");
    println!("{}", exp.fig5(n).unwrap());
}
