//! Reproduces Figure 5 (parallel-workload knobs: tasks/round, samples,
//! chunk granularity) and Figure 4 (capacity vs accuracy + communication
//! efficiency / information-bottleneck view).
//!
//! `--parallel N` evaluates samples over N pool workers; tables are
//! bit-identical to the serial run while concurrent samples coalesce in
//! the shared batcher (the occupancy line below shows the effect).
use minions::exp::Exp;
use minions::util::cli::Cli;

fn main() {
    let cli = Cli::new("fig5_parallel", "Figures 4-5 reproduction")
        .opt("backend", "pjrt | native (equivalence asserted by tests)", Some("native"))
        .opt("n", "samples per point", Some("16"))
        .opt("seed", "seed", Some("42"))
        .parallel_opt();
    let a = cli.parse();
    let n = a.parse_num("n", 16);
    let mut exp = Exp::new(a.get_or("backend", "pjrt"), a.parse_num("seed", 42)).expect("startup");
    exp.parallel = a.parse_num("parallel", 1usize).max(1);
    println!("== Figure 4: model-size series ==");
    println!("{}", exp.fig4(n).unwrap());
    println!("== Figure 5: parallel-workload knobs ==");
    println!("{}", exp.fig5(n).unwrap());
    let b = exp.batcher_snapshot();
    println!("hot path: {b} ({} threads)", exp.parallel);
}
