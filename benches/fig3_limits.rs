//! Reproduces Figure 3 / Tables 4-5: small-LM limitation micro-benchmarks
//! (context-length and multi-step degradation + decomposed counterpart).
use minions::exp::Exp;
use minions::util::cli::Cli;

fn main() {
    let cli = Cli::new("fig3_limits", "Figure 3 / Tables 4-5 reproduction")
        .opt("backend", "pjrt | native (equivalence asserted by tests)", Some("native"))
        .opt("n", "samples per point", Some("32"))
        .opt("seed", "seed", Some("42"));
    let a = cli.parse();
    let mut exp = Exp::new(a.get_or("backend", "pjrt"), a.parse_num("seed", 42)).expect("startup");
    println!("{}", exp.fig3(a.parse_num("n", 32)).unwrap());
}
