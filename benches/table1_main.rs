//! Reproduces Table 1 / Table 6 / Figure 2: the main accuracy-cost grid
//! plus Table 2 (remote sweep) and Table 3 (retrospective).
//! Run: cargo bench --bench table1_main [-- --n 32 --backend pjrt]
use minions::exp::Exp;
use minions::util::cli::Cli;

fn main() {
    let cli = Cli::new("table1_main", "Table 1/2/3 + Figure 2 reproduction")
        .opt("backend", "pjrt | native (equivalence asserted by tests)", Some("native"))
        .opt("n", "samples per dataset", Some("24"))
        .opt("seed", "seed", Some("42"));
    let a = cli.parse();
    let n = a.parse_num("n", 24);
    let mut exp = Exp::new(a.get_or("backend", "pjrt"), a.parse_num("seed", 42)).expect("startup");
    println!("== Table 1 / Table 6 (n={n}) ==");
    println!("{}", exp.table1(n, Some(std::path::Path::new("figure2.csv"))).unwrap());
    println!("(figure2.csv written: cost vs macro-accuracy scatter)");
    println!("== Table 2: remote model sweep ==");
    println!("{}", exp.table2(n.min(16)).unwrap());
    println!("== Table 3: point-in-time retrospective ==");
    println!("{}", exp.table3(n.min(16)).unwrap());
}
