//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf) + Appendix C latency
//! model table.
//!
//! Measures: PJRT dispatch latency per capacity, end-to-end MinionS
//! queries/sec, dynamic-batcher occupancy under raw concurrent rows,
//! cross-sample batch coalescing (serial vs parallel eval through the
//! shared batcher — occupancy before/after), repeated-chunk cache
//! hit-rate and wall-clock (cold vs warm re-query of the same
//! documents), contended lane fairness (interactive p50/p95 wait under a
//! saturating batch sweep, FIFO vs weighted lanes), and prints the
//! analytical latency ratios with the Prop C.1 bound.
//!
//! Exits cleanly when the compiled artifacts are absent so the CI bench
//! smoke step can run in artifact-less environments.
//!
//! `--json` switches to the machine-readable perf report instead: the
//! `minions-bench-v1` document (kernel reference-vs-factored rows/sec,
//! engine worker-pool scaling, pooled-query memo and chunk-cache hit
//! rates) written to `--out` (default `BENCH_runtime_hotpath.json`).
//! JSON mode synthesizes deterministic artifacts when the real set is
//! absent, so it produces a report everywhere — including CI.

use minions::data;
use minions::eval::{run_protocol, run_protocol_parallel};
use minions::exp::Exp;
use minions::latency::*;
use minions::model::{local, remote};
use minions::protocol::{Protocol, ProtocolSpec};
use minions::runtime::{default_artifact_dir, ScoreRequest};
use minions::sched::{lane_scope, DynamicBatcher, Lane, ScoreRow, Ticket};
use minions::util::cli::Cli;
use minions::util::rng::Rng;
use minions::util::stats::{bench, fmt_duration, Summary, Table};
use minions::vocab::{BATCH, CHUNK, QLEN};
use std::collections::VecDeque;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

fn flat_row(d: usize) -> ScoreRow {
    ScoreRow {
        d,
        q_tokens: vec![0i32; QLEN],
        q_weights: vec![0.2; QLEN],
        c_tokens: vec![0i32; CHUNK],
        c_mask: vec![1.0; CHUNK],
    }
}

fn rand_request(d: usize, rng: &mut Rng) -> ScoreRequest {
    ScoreRequest {
        d,
        q_tokens: (0..BATCH * QLEN).map(|_| rng.range(16, 4096) as i32).collect(),
        q_weights: vec![0.2; BATCH * QLEN],
        c_tokens: (0..BATCH * CHUNK).map(|_| rng.range(4096, 8192) as i32).collect(),
        c_mask: vec![1.0; BATCH * CHUNK],
    }
}

fn main() {
    let cli = Cli::new("runtime_hotpath", "hot-path microbenchmarks + latency model")
        .opt("backend", "pjrt | native", Some("pjrt"))
        .opt("iters", "measured iterations", Some("20"))
        .opt("seed", "seed", Some("42"))
        .flag("json", "write the minions-bench-v1 perf report and exit")
        .opt("out", "json: report path", Some("BENCH_runtime_hotpath.json"))
        .opt(
            "scale-requests",
            "json: score requests per engine-scaling point",
            None,
        );
    let a = cli.parse();
    let iters: usize = a.parse_num("iters", 20);
    if a.flag("json") {
        let seed: u64 = a.parse_num("seed", 42);
        let mut opts = minions::perf::HotpathOptions {
            seed,
            iters: iters.max(1),
            ..Default::default()
        };
        opts.scale_requests = a.parse_num("scale-requests", opts.scale_requests).max(1);
        let (manifest, synthetic) =
            minions::perf::load_or_synth_manifest(&[64, 128], seed).expect("manifest");
        let report =
            minions::perf::hotpath_report(&manifest, &opts, synthetic).expect("hotpath report");
        let out = std::path::PathBuf::from(a.get_or("out", "BENCH_runtime_hotpath.json"));
        minions::perf::write_report(&out, &report).expect("write report");
        println!("wrote {}", out.display());
        return;
    }
    if !default_artifact_dir().join("manifest.json").exists() {
        eprintln!("skipping runtime_hotpath: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut exp = Exp::new(a.get_or("backend", "pjrt"), a.parse_num("seed", 42)).expect("startup");
    // the raw-scoring exhibits (end-to-end throughput, coalescing) must
    // not be short-circuited by the chunk cache — give them their own
    // cache-free harness; the cache exhibit below uses `exp`'s default
    let mut exp_nc =
        Exp::new(a.get_or("backend", "pjrt"), a.parse_num("seed", 42)).expect("startup");
    exp_nc.set_cache(None);
    let mut rng = Rng::seed_from(7);

    // --- dispatch latency per capacity ---
    println!("== PJRT score-dispatch latency (B={BATCH}, C={CHUNK}) ==");
    let mut t = Table::new(&["d", "mean", "p50", "p95", "rows/s"]);
    for d in [64usize, 128, 256, 1024] {
        let req = rand_request(d, &mut rng);
        let backend = Arc::clone(&exp.backend);
        let s = bench(3, iters, || {
            backend.score(req.clone()).unwrap();
        });
        t.row(vec![
            d.to_string(),
            fmt_duration(s.mean),
            fmt_duration(s.p50),
            fmt_duration(s.p95),
            format!("{:.0}", BATCH as f64 / s.mean),
        ]);
    }
    println!("{}", t.render());

    // --- end-to-end MinionS throughput (uncached) ---
    let ds = data::generate("finance", 8, 3);
    let proto = exp_nc
        .protocol(&ProtocolSpec::minions(local::LLAMA_8B.name, remote::GPT_4O.name))
        .expect("minions protocol");
    let s = bench(1, 3, || {
        run_protocol(proto.as_ref(), &ds, 5, true).unwrap();
    });
    println!(
        "== end-to-end MinionS ==\n8 finance queries: {} per batch ({:.2} queries/s)\n",
        fmt_duration(s.mean),
        8.0 / s.mean
    );

    // --- dynamic batcher occupancy under concurrent load ---
    let batcher = DynamicBatcher::new(
        Arc::clone(&exp.backend),
        std::time::Duration::from_millis(5),
    );
    let n_rows = 64;
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..n_rows)
        .map(|i| {
            let b = Arc::clone(&batcher);
            std::thread::spawn(move || {
                let mut rng = Rng::seed_from(i as u64);
                let row = ScoreRow {
                    d: 128,
                    q_tokens: (0..QLEN).map(|_| rng.range(16, 4096) as i32).collect(),
                    q_weights: vec![0.2; QLEN],
                    c_tokens: (0..CHUNK).map(|_| rng.range(4096, 8192) as i32).collect(),
                    c_mask: vec![1.0; CHUNK],
                };
                b.score_row(row).unwrap();
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let elapsed = t0.elapsed().as_secs_f64();
    println!(
        "== dynamic batcher ==\n{n_rows} concurrent rows in {}: occupancy {:.2}, {} dispatches\n",
        fmt_duration(elapsed),
        batcher.stats.occupancy(),
        batcher
            .stats
            .dispatches
            .load(std::sync::atomic::Ordering::Relaxed)
    );
    batcher.stop();

    // --- cross-sample coalescing: serial vs parallel eval ---
    // Small contexts + 1 task/round mean each sample alone dispatches a
    // 2-row partial batch; parallel samples share the batcher, so their
    // rows coalesce and occupancy rises with thread count while
    // wall-clock drops. This is the ISSUE's before/after exhibit.
    let ds_small = data::micro::context_sweep(2, 16, 11);
    let mut coalesce_spec = ProtocolSpec::minions(local::LLAMA_3B.name, remote::GPT_4O.name);
    coalesce_spec.tasks_per_round = 1;
    let coalesce_proto: Arc<dyn Protocol> =
        exp_nc.protocol(&coalesce_spec).expect("coalescing protocol");
    println!("== cross-sample coalescing (16 samples, 1 task/round, 2 chunks) ==");
    let mut t = Table::new(&["eval threads", "wall", "queries/s", "occupancy", "dispatches"]);
    let mut serial_wall = None;
    for threads in [1usize, 4, 8] {
        let before = exp_nc.batcher_snapshot();
        let t0 = std::time::Instant::now();
        let r = run_protocol_parallel(Arc::clone(&coalesce_proto), &ds_small, 5, true, threads)
            .expect("coalescing run");
        let wall = t0.elapsed().as_secs_f64();
        let after = exp_nc.batcher_snapshot();
        if threads == 1 {
            serial_wall = Some((wall, after.occupancy_since(&before), r.accuracy));
        }
        t.row(vec![
            threads.to_string(),
            fmt_duration(wall),
            format!("{:.1}", ds_small.samples.len() as f64 / wall),
            format!("{:.2}", after.occupancy_since(&before)),
            (after.dispatches - before.dispatches).to_string(),
        ]);
        if let Some((sw, so, sacc)) = serial_wall {
            if threads > 1 {
                assert_eq!(r.accuracy, sacc, "parallel eval must be bit-identical");
                if threads == 8 {
                    println!(
                        "coalescing gain: occupancy {:.2} -> {:.2}, wall {} -> {} ({:.1}x)",
                        so,
                        after.occupancy_since(&before),
                        fmt_duration(sw),
                        fmt_duration(wall),
                        sw / wall
                    );
                }
            }
        }
    }
    println!("{}", t.render());

    // --- repeated-chunk cache: cold vs warm re-query of one corpus ---
    // The serving-side win ISSUE 2 targets: a client (or many clients)
    // re-querying the same documents re-executes identical chunk×task
    // jobs, which the ChunkCache serves without touching the batcher.
    // Results are bit-identical (asserted below and, exhaustively, in
    // tests/cache_parity.rs); only the work disappears.
    let cache = exp.cache().expect("harness cache on by default");
    let ds_docs = data::generate("finance", 8, 23);
    let cache_proto = exp
        .protocol(&ProtocolSpec::minions(local::LLAMA_3B.name, remote::GPT_4O.name))
        .expect("cache protocol");
    println!("== repeated-chunk cache (8 finance queries, re-queried) ==");
    let mut t = Table::new(&["pass", "wall", "hit rate", "dispatches", "cached rows"]);
    let mut cold_result = None;
    for pass in ["cold", "warm"] {
        let c0 = cache.snapshot();
        let b0 = exp.batcher_snapshot();
        let t0 = std::time::Instant::now();
        let r = run_protocol(cache_proto.as_ref(), &ds_docs, 9, true).expect("cache pass");
        let wall = t0.elapsed().as_secs_f64();
        let c1 = cache.snapshot();
        let b1 = exp.batcher_snapshot();
        t.row(vec![
            pass.into(),
            fmt_duration(wall),
            format!("{:.2}", c1.hit_rate_since(&c0)),
            (b1.dispatches - b0.dispatches).to_string(),
            (b1.cached_rows - b0.cached_rows).to_string(),
        ]);
        if let Some((cold_acc, cold_wall)) = cold_result {
            assert_eq!(r.accuracy, cold_acc, "cached run must be bit-identical");
            assert_eq!(
                b1.dispatches, b0.dispatches,
                "warm pass must add zero dispatches"
            );
            println!(
                "cache gain: wall {} -> {} ({:.1}x), hit rate {:.2}",
                fmt_duration(cold_wall),
                fmt_duration(wall),
                cold_wall / wall,
                c1.hit_rate_since(&c0)
            );
        } else {
            cold_result = Some((r.accuracy, wall));
        }
    }
    println!("{}", t.render());

    // --- contended lane fairness: interactive wait under a batch sweep ---
    // Two batch-lane flooders keep the scheduler saturated while a
    // client submits interactive rows one at a time. "fifo" collapses
    // every submitter onto one lane and session (the pre-QoS behavior:
    // interactive rows queue behind the sweep's backlog); "wfq 4:1" tags
    // lanes properly, so the fair assembly pulls each interactive row
    // into the next flush. This is the ISSUE-3 fairness exhibit.
    println!("== lane fairness: interactive wait under a saturating batch sweep ==");
    let mut t = Table::new(&["scenario", "p50 wait", "p95 wait", "max", "batch rows"]);
    for (label, lanes_on) in [("fifo (no lanes)", false), ("wfq lanes 4:1", true)] {
        let fb = DynamicBatcher::new(
            Arc::clone(&exp.backend),
            std::time::Duration::from_millis(2),
        );
        let (iw, bw) = if lanes_on { (4, 1) } else { (1, 1) };
        fb.set_lane_weights(iw, bw);
        let stop = Arc::new(AtomicBool::new(false));
        let flood: Vec<_> = (0..2u64)
            .map(|f| {
                let fb = Arc::clone(&fb);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    // in the no-lanes scenario everyone shares one
                    // (lane, session), i.e. one FIFO queue
                    let (lane, session) = if lanes_on {
                        (Lane::Batch, f)
                    } else {
                        (Lane::Batch, 0)
                    };
                    let _lane = lane_scope(lane, session);
                    let mut parked: VecDeque<Ticket> = VecDeque::new();
                    while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                        while parked.len() < 32 {
                            match fb.submit(flat_row(128)) {
                                Ok(ticket) => parked.push_back(ticket),
                                Err(_) => break,
                            }
                        }
                        if let Some(ticket) = parked.pop_front() {
                            let _ = ticket.wait();
                        }
                    }
                    for ticket in parked {
                        let _ = ticket.wait();
                    }
                })
            })
            .collect();
        // let the sweep build up before measuring
        std::thread::sleep(std::time::Duration::from_millis(20));
        let _lane = if lanes_on {
            lane_scope(Lane::Interactive, 99)
        } else {
            lane_scope(Lane::Batch, 0)
        };
        let mut waits_ms = Vec::with_capacity(30);
        for _ in 0..30 {
            let t0 = std::time::Instant::now();
            fb.score_row(flat_row(128)).expect("interactive row");
            waits_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        for h in flood {
            h.join().unwrap();
        }
        let batch_rows = fb.snapshot().lane_rows[Lane::Batch.index()];
        fb.stop();
        let s = Summary::of(&waits_ms);
        t.row(vec![
            label.into(),
            format!("{:.2}ms", s.p50),
            format!("{:.2}ms", s.p95),
            format!("{:.2}ms", s.max),
            batch_rows.to_string(),
        ]);
    }
    println!("{}", t.render());

    // --- Appendix C latency model ---
    println!("== Appendix C analytical latency (Llama-8B@4090 + Llama-405B@8xH100) ==");
    let mut t = Table::new(&["n (tokens)", "T_remote", "T_minionS", "ratio", "Prop C.1 bound"]);
    for n in [50_000.0f64, 100_000.0, 200_000.0] {
        let (c, k, s_, p) = (16.0, 2.0, 1.0, 0.3);
        let n_out_l = 64.0;
        let a_frac = n_out_l * p * c * k * s_ / n;
        let t_r = t_remote(&LLAMA_405B, &H100_NODE, n, 128.0);
        let t_m = t_minions_local(&LLAMA_8B, &RTX_4090, n, n_out_l, c, k, s_, p)
            + t_minions_remote(&LLAMA_405B, &H100_NODE, n_out_l * p * c * k * s_, 128.0);
        let bound = prop_c1_bound(&LLAMA_8B, &RTX_4090, &LLAMA_405B, &H100_NODE, a_frac);
        t.row(vec![
            format!("{n:.0}"),
            format!("{:.2}s", t_r),
            format!("{:.2}s", t_m),
            format!("{:.2}x", t_m / t_r),
            format!("{bound:.2}x"),
        ]);
    }
    println!("{}", t.render());
}
