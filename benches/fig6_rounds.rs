//! Reproduces Figure 6 (Minion rounds sweep) and Figure 7 (MinionS
//! retries-vs-scratchpad round strategies).
use minions::exp::Exp;
use minions::util::cli::Cli;

fn main() {
    let cli = Cli::new("fig6_rounds", "Figures 6-7 reproduction")
        .opt("backend", "pjrt | native (equivalence asserted by tests)", Some("native"))
        .opt("n", "samples per dataset", Some("12"))
        .opt("seed", "seed", Some("42"));
    let a = cli.parse();
    let mut exp = Exp::new(a.get_or("backend", "pjrt"), a.parse_num("seed", 42)).expect("startup");
    println!("{}", exp.fig6(a.parse_num("n", 12)).unwrap());
}
