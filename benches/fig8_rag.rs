//! Reproduces Figure 8 (RAG vs Minion(S) on finance, retrieval-k sweep)
//! and Tables 7-8 (summarisation rubric: MinionS ≈ remote-only > RAG).
use minions::exp::Exp;
use minions::util::cli::Cli;

fn main() {
    let cli = Cli::new("fig8_rag", "Figure 8 + Table 7 reproduction")
        .opt("backend", "pjrt | native (equivalence asserted by tests)", Some("native"))
        .opt("n", "samples", Some("16"))
        .opt("seed", "seed", Some("42"));
    let a = cli.parse();
    let n = a.parse_num("n", 16);
    let mut exp = Exp::new(a.get_or("backend", "pjrt"), a.parse_num("seed", 42)).expect("startup");
    println!("== Figure 8: RAG vs local-remote on finance ==");
    println!("{}", exp.fig8(n).unwrap());
    println!("== Table 7: summarisation rubric (BooookScore analogue) ==");
    println!("{}", exp.summarization(n.min(8)).unwrap());
}
